"""`serve.connect`: the one serving entry point (DESIGN.md §11).

The pre-plan API exposed three divergent entry points — ``ServeEngine``,
``ContinuousEngine``, ``fabric.Router`` — each with its own pile of
per-call knobs.  Following the paper authors' follow-up argument (stop
exposing user-visible endpoints; let callers declare intent and streams,
resolve resources internally), callers now do:

    client = serve.connect(cfg, "shared_dynamic", params=params)
    client = serve.connect(cfg, Hints(latency_target_ms=80,
                                      burstiness=0.9), n_workers=8)
    client = serve.connect(cfg, SharingVector(slots=1, channels=3))

    s = client.stream()                  # ordered lane (MPIX-stream-like)
    s.submit(prompt_a); s.submit(prompt_b)
    client.submit(prompt_c)              # unordered: free concurrency
    tokens = client.run()                # {rid: [generated tokens]}

``connect`` resolves anything plan-shaped (``core.plan.as_plan``) into an
``EndpointPlan`` and the client picks the executor: a fleet of
continuous-batching workers behind the fabric router when
``plan.n_workers > 1``, a single ``ContinuousEngine`` otherwise, or the
legacy wave engine when the plan says ``executor="wave"``.  The old
classes survive as these internal executors; every knob they used to take
lives on the plan.

**Stream semantics.**  A ``Stream`` is an ordered lane: its requests
start AND finish in submission order (request *i+1* is released into the
engine only after request *i* retires), while different streams — and all
unordered submissions — run concurrently.  In fleet mode a stream
additionally carries its id as the fabric session key, so
session-affinity placement pins the lane to one channel group (the
stream → channel-group mapping); in single-engine mode the lane occupies
at most one slot of the pool's admission groups at a time (the stream →
slot-group mapping).  Ordering changes WHEN tokens are produced, never
their values.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Union

import jax
import numpy as np

from repro.core.adapt import Replanner, WindowStats
from repro.core.plan import EndpointPlan, Hints, SharingVector, as_plan
from repro.models.model import Model
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (NOOP_OBS, Observability, PID_REQUESTS)
from repro.serve.engine import ContinuousEngine, Request, ServeEngine
from repro.serve.fabric.faults import FaultPlan
from repro.serve.fabric.placement import POLICIES
from repro.serve.fabric.router import (Completion, EngineWorker,
                                       FabricCosts, FleetReport, Router)
from repro.serve.fabric.traffic import Arrival
from repro.serve.recovery import RecoveryPolicy

#: Plan fields a live ``replan`` may NOT change: they size caches,
#: compiled shapes, or the worker fleet itself — migrating them would
#: mean evicting in-flight requests, which the migration contract forbids.
STRUCTURAL_FIELDS = ("n_workers", "n_slots", "max_len", "decode_horizon",
                     "prefill_buckets", "use_ragged_kernel", "executor",
                     "page_size", "page_budget", "roles")

# fabric session keys for streams live above any plausible caller-supplied
# session id, so a stream's affinity key can never alias a user session
_STREAM_SESSION_BASE = 1 << 32


@dataclasses.dataclass
class _Pending:
    """One submitted request waiting for the next ``run()``."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int]
    sid: Optional[int]                # stream id; None = unordered
    at_ns: float                      # virtual arrival time (fleet mode)
    session: int = -1                 # affinity key for unordered requests


class Stream:
    """An ordered lane of one ``ServeClient`` (explicit, MPIX-style).

    Requests submitted to a stream complete in submission order; distinct
    streams progress concurrently.  Obtain one via ``client.stream()``.
    """

    def __init__(self, client: "ServeClient", sid: int,
                 name: Optional[str] = None):
        self.client = client
        self.sid = sid
        self.name = name or f"stream{sid}"
        self.rids: List[int] = []

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None, at_ns: float = 0.0) -> int:
        return self.client.submit(prompt, max_new_tokens=max_new_tokens,
                                  eos_id=eos_id, stream=self, at_ns=at_ns)

    @property
    def outputs(self) -> List[Optional[List[int]]]:
        """This stream's generated tokens, in submission order (None for
        requests the client has not run yet)."""
        return [self.client.results.get(r) for r in self.rids]

    def __repr__(self):
        return f"Stream({self.name!r}, sid={self.sid}, " \
               f"requests={len(self.rids)})"


class ServeClient:
    """A connected serving session over one resolved ``EndpointPlan``.

    Build via ``serve.connect``.  ``submit`` queues work (optionally on a
    ``Stream``), ``run`` drains everything queued so far and returns
    ``{rid: [tokens]}``; ``results`` accumulates across runs.
    """

    def __init__(self, cfg, params, plan: EndpointPlan,
                 obs: Optional[Observability] = None,
                 faults: Union[FaultPlan, str, None] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 plan_repository=None, migrations=None):
        if plan.placement not in POLICIES:
            raise ValueError(f"unknown placement {plan.placement!r}; "
                             f"one of {sorted(POLICIES)}")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        #: tuned-plan store (DESIGN.md §16, duck-typed
        #: ``tune.PlanRepository``): consulted by hint re-resolution in
        #: ``replan`` and handed to the adaptive controller so live
        #: transitions jump to measured frontier plans.  None = the
        #: historical analytic/hysteresis behavior, bit-identical.
        self.plan_repository = plan_repository
        #: observability bundle (DESIGN.md §14): defaults to the no-op
        #: recorder/registry; ``connect(..., obs=enabled_obs())`` records
        #: every run's spans + metrics for --trace-out / --metrics-out
        self.obs = obs if obs is not None else NOOP_OBS
        self.executor = plan.resolved_executor
        if (faults is not None or recovery is not None
                or migrations) and self.executor != "fleet":
            raise ValueError(
                "fault injection / crash recovery / live migration live "
                "on the fleet fabric (plan.n_workers > 1); this plan "
                f"resolved to the {self.executor!r} executor")
        #: chaos fabric (DESIGN.md §15): a FaultPlan (or its string
        #: grammar) injected into every run's router; ``recovery`` tunes
        #: detection/backoff/shedding.  Both None = today's fault-free
        #: event stream, bit-identical.
        self.faults = faults
        self.recovery = recovery
        #: scheduled decode→decode live migrations (DESIGN.md §17):
        #: (t_ns, src_worker, dst_worker) triples the router drains at
        #: their virtual times on EVERY fleet run — the source worker's
        #: live sessions leave as KV handoffs and resume on the
        #: destination mid-stream, token streams bit-identical
        self.migrations = list(migrations) if migrations else None
        self.results: Dict[int, List[int]] = {}
        #: exactly-once delivery cursor: tokens of ``results[rid]``
        #: already surfaced to the caller.  Completion replays (a retry
        #: racing its original, a duplicate splice) append only the
        #: tokens past the cursor — never double-deliver, never reorder.
        self._cursor: Dict[int, int] = {}
        #: replays that DISAGREED with already-delivered tokens
        #: (first-wins; structurally impossible under fail-stop, counted
        #: defensively)
        self.dedup_conflicts = 0
        self.report: Optional[FleetReport] = None   # last fleet report
        #: live migrations applied so far: (schedule key, vector) —
        #: virtual ns in fleet mode, engine step count in single-engine
        self.transitions: List = []
        self._pending: List[_Pending] = []
        self._requests: Dict[int, _Pending] = {}
        self._streams: List[Stream] = []
        self._next_rid = 0
        self._closed = False
        self.engine = None            # single-executor engine
        self.workers: List[EngineWorker] = []
        if self.executor == "wave":
            self.engine = ServeEngine(cfg, params, plan=plan)
        elif self.executor == "continuous":
            self.engine = ContinuousEngine(cfg, params, plan=plan,
                                           exec_group=plan.exec_group_of(0))
        # fleet workers are built lazily on the first run()

    # ----- submission -----------------------------------------------------
    def stream(self, name: Optional[str] = None) -> Stream:
        """A new ordered lane.  Wave execution cannot order (one static
        wave is the level-4 extreme), so streams need a continuous or
        fleet executor."""
        if self.executor == "wave":
            raise ValueError("ordered streams need the continuous or "
                             "fleet executor; the wave engine is one "
                             "unordered static wave")
        s = Stream(self, len(self._streams), name)
        self._streams.append(s)
        return s

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               stream: Union[Stream, int, None] = None,
               at_ns: float = 0.0, session: int = -1) -> int:
        """Queue one request; -> its rid.  ``stream`` orders it behind
        the stream's earlier requests; ``at_ns`` is its virtual arrival
        time in fleet mode (ignored by the single-engine executors, which
        are closed-loop); ``session`` is a placement-affinity key for
        unordered requests (a stream already carries its own)."""
        if self._closed:
            raise RuntimeError("client is closed")
        if isinstance(stream, Stream):
            if stream.client is not self:
                raise ValueError("stream belongs to a different client")
        elif stream is not None:
            stream = self._streams[stream]
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if self.executor != "wave" and len(prompt) >= self.plan.max_len:
            # the continuous engines (and fleet accounting) need the
            # prompt to fit; the wave engine instead truncates the decode
            # budget at the cache edge — a supported legacy mode
            raise ValueError(f"prompt of {len(prompt)} tokens cannot fit "
                             f"max_len={self.plan.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        p = _Pending(rid=rid, prompt=prompt,
                     max_new_tokens=int(max_new_tokens), eos_id=eos_id,
                     sid=stream.sid if stream is not None else None,
                     at_ns=float(at_ns), session=int(session))
        self._pending.append(p)
        self._requests[rid] = p
        if stream is not None:
            stream.rids.append(rid)
        return rid

    def generate(self, prompts, max_new_tokens: int = 16) -> List[List[int]]:
        """Convenience: submit a batch of unordered prompts, run, and
        return their outputs in input order."""
        rids = [self.submit(p, max_new_tokens=max_new_tokens)
                for p in prompts]
        out = self.run()
        return [out[r] for r in rids]

    # ----- execution ------------------------------------------------------
    def run(self) -> Dict[int, List[int]]:
        """Serve everything queued since the last run; -> their
        ``{rid: [tokens]}`` (also merged into ``results``)."""
        if self._closed:
            raise RuntimeError("client is closed")
        batch, self._pending = self._pending, []
        if not batch:
            return {}
        if self.executor == "fleet":
            out = self._run_fleet(batch)
        elif self.executor == "wave":
            out = self._run_wave(batch)
        else:
            out = self._run_continuous(batch)
        missing = {p.rid for p in batch} - out.keys()
        if missing and self.report is not None:
            # shed / retry-exhausted requests are ACCOUNTED losses (the
            # report names them); stream successors behind a dropped
            # head return to the pending queue for the next run()
            dropped = ({rid for rid, _, _ in self.report.shed}
                       | set(self.report.failed)
                       | {p.rid for p in self._pending})
            missing -= dropped
        assert not missing, f"requests lost by the executor: {missing}"
        self.results.update(out)
        return out

    def _ingest(self, rid: int, tokens) -> List[int]:
        """Fold a completion's token list into ``results[rid]`` through
        the exactly-once cursor: the overlap with what was already
        delivered must agree (first delivery wins; a disagreement bumps
        ``dedup_conflicts`` and is dropped), and only the suffix past
        the cursor is appended.  Idempotent under replays."""
        tokens = [int(x) for x in tokens]
        got = self.results.setdefault(rid, [])
        cur = self._cursor.get(rid, len(got))
        overlap = min(cur, len(tokens))
        if tokens[:overlap] != got[:overlap]:
            self.dedup_conflicts += 1
            return got
        got.extend(tokens[cur:])
        self._cursor[rid] = len(got)
        return got

    # ----- fault-tolerance views (populated by fleet runs) ----------------
    @property
    def shed(self) -> List:
        """Requests refused before acceptance: (rid, reason, t_ns)."""
        return list(self.report.shed) if self.report is not None else []

    @property
    def failed(self) -> List[int]:
        """Requests that exhausted their retry budget."""
        return list(self.report.failed) if self.report is not None else []

    def _request(self, p: _Pending) -> Request:
        return Request(rid=p.rid, prompt=p.prompt,
                       max_new_tokens=p.max_new_tokens, eos_id=p.eos_id)

    def _split(self, batch):
        """-> (unordered pendings, {sid: deque of its pendings})."""
        unordered, streams = [], {}
        for p in batch:
            if p.sid is None:
                unordered.append(p)
            else:
                streams.setdefault(p.sid, deque()).append(p)
        return unordered, streams

    def _run_wave(self, batch) -> Dict[int, List[int]]:
        eng = self.engine
        for p in batch:
            eng.submit(self._request(p))
        rids = {p.rid for p in batch}
        eng.run()
        return {r.rid: list(r.output) for r in eng.done if r.rid in rids}

    def _run_continuous(self, batch) -> Dict[int, List[int]]:
        """Drive the single engine's external-stepping hooks, releasing
        each stream's next request only once its predecessor retires —
        per-stream FIFO over the slot pool, cross-stream concurrency.
        With ``plan.adaptive`` a ``Replanner`` samples the engine's own
        counters every window (windows sized in decode steps via the
        fabric cost model, so one knob paces both executors) and its
        proposals land through ``_apply_vector`` — the same path manual
        ``replan`` takes."""
        eng = self.engine
        unordered, streams = self._split(batch)
        inflight = {sid: None for sid in streams}
        for p in unordered:
            eng.submit(self._request(p))
        out: Dict[int, List[int]] = {}
        eng.start()
        # latency baseline per run(), exactly as ContinuousEngine.run()
        # re-baselines (start() is idempotent and keeps the first _t0)
        eng._t0 = time.perf_counter()
        adapt = self._make_replanner() if self.plan.adaptive else None
        win_steps = max(1, int(self.plan.adapt_window_ns
                               // FabricCosts().t_step_base_ns))
        # single-engine window accounting runs through the same metrics
        # fabric the fleet router uses (DESIGN.md §14): the engine
        # publishes its absolute counters, the registry window diffs
        # them — no hand-threaded stats-dict marks
        reg = (self.obs.metrics if self.obs.metrics.enabled
               else MetricsRegistry())
        eng.publish_metrics(reg, worker=0)
        win = reg.window()
        step_mark = eng.stats["decode_steps"]
        while True:
            for sid in sorted(streams):
                if inflight[sid] is None and streams[sid]:
                    p = streams[sid].popleft()
                    eng.submit(self._request(p))
                    inflight[sid] = p.rid
            if not eng.has_work:
                break
            eng.admit_waiting()
            for r in eng.step():
                out[r.rid] = list(r.output)
                sid = self._requests[r.rid].sid
                if sid is not None and inflight.get(sid) == r.rid:
                    inflight[sid] = None
            if adapt is not None and eng.stats["decode_steps"] \
                    - step_mark >= win_steps:
                step_mark = eng.stats["decode_steps"]
                eng.publish_metrics(reg, worker=0)
                d_slot = win.delta("engine.slot_steps", axis="slots",
                                   worker=0)
                d_busy = win.delta("engine.busy_slot_steps", axis="slots",
                                   worker=0)
                d_compiles = win.delta_total("engine.jit_compiles")
                win.roll()
                vec = adapt.observe(WindowStats(
                    occupancy=d_busy / d_slot if d_slot else 0.0,
                    queue_depth=float(len(eng.queue)),
                    jit_compiles=max(0, int(d_compiles)),
                    tokens=int(d_busy),
                    page_pressure=(eng.page_pool.pressure()
                                   if eng.paged else 0.0)))
                if vec is not None:
                    self._apply_vector(vec)
                    self.transitions.append((eng._step_no, vec))
        eng.publish_metrics(reg, worker=0)
        if self.obs.tracing:
            self._record_engine_spans(out)
        if adapt is not None and adapt.vector != self.plan.vector:
            self.plan = dataclasses.replace(self.plan, preset=None,
                                            vector=adapt.vector)
        return out

    def _record_engine_spans(self, out: Dict[int, List[int]]) -> None:
        """Post-hoc request-lifecycle spans for the single continuous
        engine: it runs closed-loop on the host clock, so spans are laid
        out on the engine's deterministic step counter scaled by the
        fabric cost model's step cost — the same virtual-ns axis fleet
        traces use (wall clock never enters the trace)."""
        rec = self.obs.recorder
        base = FabricCosts().t_step_base_ns
        eng = self.engine
        for rid in sorted(out):
            a = eng.admit_steps.get(rid)
            r = eng.retire_steps.get(rid)
            if a is None or r is None:
                continue
            rec.begin(PID_REQUESTS, "request", rid, a * base,
                      args={"admit_step": a})
            rec.end(PID_REQUESTS, "request", rid, r * base,
                    args={"retire_step": r,
                          "new_tokens": len(out[rid])})

    def _build_workers(self):
        plan = self.plan

        def request_fn(arrival: Arrival) -> Request:
            return self._request(self._requests[arrival.rid])

        self.workers = [
            EngineWorker(
                w,
                ContinuousEngine(self.cfg, self.params, plan=plan,
                                 exec_group=plan.exec_group_of(w)),
                request_fn=request_fn)
            for w in range(plan.n_workers)]

    def _run_fleet(self, batch) -> Dict[int, List[int]]:
        """One router pass over fresh channels (the engines persist and
        keep their jitted state): unordered requests and stream heads
        enter at their arrival times; each completion of a stream request
        releases the stream's next via the router's ``on_complete`` hook
        — per-stream FIFO mapped onto the channel groups."""
        if not self.workers:
            self._build_workers()
        unordered, waiting = self._split(batch)

        def arrival(p: _Pending, t_ns: float) -> Arrival:
            return Arrival(rid=p.rid, t_ns=t_ns,
                           prompt_len=len(p.prompt),
                           max_new_tokens=p.max_new_tokens,
                           session=(p.session if p.sid is None
                                    else _STREAM_SESSION_BASE + p.sid))

        trace = [arrival(p, p.at_ns) for p in unordered]
        for q in waiting.values():
            head = q.popleft()
            trace.append(arrival(head, head.at_ns))
        trace.sort(key=lambda a: (a.t_ns, a.rid))

        def on_complete(c: Completion):
            # stream tokens through the exactly-once cursor as they
            # complete (the final loop below replays idempotently)
            self._ingest(c.rid, c.output)
            sid = self._requests[c.rid].sid
            if sid is None or not waiting.get(sid):
                return ()
            nxt = waiting[sid].popleft()
            return [arrival(nxt, max(nxt.at_ns, c.t_done_ns))]

        adapt = self._make_replanner() if self.plan.adaptive else None
        router = Router(self.workers, self.plan,
                        placement=self.plan.placement,
                        on_complete=on_complete, adapt=adapt,
                        adapt_window_ns=self.plan.adapt_window_ns,
                        obs=self.obs, faults=self.faults,
                        recovery=self.recovery,
                        migrations=self.migrations)
        self.report = router.run(trace)
        if adapt is not None:
            self.transitions.extend(self.report.transitions)
            if router.vector != self.plan.vector:
                # the migrated vector persists: the next run()'s router
                # (and its dispatch plan) starts where this one ended
                self.plan = dataclasses.replace(self.plan, preset=None,
                                                vector=router.vector)
        # a shed/failed stream head never releases its successors: they
        # go back on the pending queue so a later run() can retry them
        # (fault-free, the waiting queues always drain — this is inert)
        for q in waiting.values():
            self._pending.extend(q)
        return {c.rid: list(self._ingest(c.rid, c.output))
                for c in self.report.completions}

    # ----- live re-planning -----------------------------------------------
    def _make_replanner(self) -> Replanner:
        """The controller for this client's plan.  If an
        ``adapt_budget`` forces the starting vector tighter than the plan
        asked for, the clamp is applied to the live stack immediately so
        the controller and the fleet never disagree."""
        plan = self.plan
        adapt = Replanner(plan.vector, n_workers=plan.n_workers,
                          n_slots=plan.n_slots, budget=plan.adapt_budget,
                          paged=plan.paged,
                          repository=self.plan_repository)
        if adapt.vector != plan.vector:
            self._apply_vector(adapt.vector)
            self.plan = dataclasses.replace(plan, preset=None,
                                            vector=adapt.vector)
        return adapt

    def _apply_vector(self, vec: SharingVector) -> None:
        """THE client-side migration executor — manual ``replan`` and the
        automatic controller both land here.  Single-engine mode re-keys
        the live engine (slot pool in place, executable group between
        dispatches); fleet mode re-keys every persistent worker engine,
        and the channel axis re-keys when the next ``run()`` builds its
        router from the updated plan (mid-run fleet channel migration is
        ``Router.apply_vector``, this method's virtual-time twin)."""
        if self.executor == "wave":
            raise ValueError("the wave executor cannot re-plan live; "
                             "adaptive plans need continuous or fleet")
        if self.executor == "continuous":
            self.engine.regroup(
                slot_level=vec.slots, exec_group=vec.exec_group_of(0, 1),
                page_level=(vec.pages if self.engine.paged else None))
        else:
            for w, worker in enumerate(self.workers):
                worker.regroup(
                    slot_level=vec.slots,
                    exec_group=vec.exec_group_of(w, self.plan.n_workers),
                    page_level=vec.pages)

    def replan(self, spec=None, **overrides) -> EndpointPlan:
        """Manually migrate this client to a new plan WITHOUT dropping
        queued work or evicting in-flight state (DESIGN.md §12).

        ``spec`` is anything ``connect`` accepts — an ``EndpointPlan``,
        ``Hints`` (re-resolved against this client's fleet shape), a
        ``SharingVector``, a preset name, or None with field overrides.
        Only the sharing vector (and placement) may change: structural
        fields (``n_workers``, ``n_slots``, ``max_len``, horizons,
        buckets, executor) are pinned to the live deployment and raise
        ``ValueError`` if a spec tries to move them.  Returns the new
        plan.  Token values are migration-invariant — pinned bit-exactly
        by the golden-trace harness."""
        if self._closed:
            raise RuntimeError("client is closed")
        plan = self.plan
        if isinstance(spec, EndpointPlan):
            new = as_plan(spec, **overrides)
        else:
            keep = {f: getattr(plan, f) for f in STRUCTURAL_FIELDS}
            keep.update(placement=plan.placement, adaptive=plan.adaptive,
                        adapt_window_ns=plan.adapt_window_ns,
                        adapt_budget=plan.adapt_budget)
            if isinstance(spec, Hints):
                # hints resolve their own placement and budget; the live
                # plan's pre-filled values would silently override them
                if spec.session_ordering:
                    keep.pop("placement")
                if spec.footprint_budget is not None:
                    keep.pop("adapt_budget")
                keep.update(overrides)
                # hint re-resolution consults the attached tuned-plan
                # repository first, exactly like connect (DESIGN.md §16)
                new = EndpointPlan.from_hints(
                    spec, repository=self.plan_repository, **keep)
            else:
                keep.update(overrides)
                new = as_plan(spec, **keep)
        for f in STRUCTURAL_FIELDS:
            if getattr(new, f) != getattr(plan, f):
                raise ValueError(
                    f"live replan cannot change {f} "
                    f"({getattr(plan, f)!r} -> {getattr(new, f)!r}); "
                    f"connect() a fresh client for structural changes")
        if new.placement not in POLICIES:
            raise ValueError(f"unknown placement {new.placement!r}; "
                             f"one of {sorted(POLICIES)}")
        if new.paged != plan.paged:
            # the PAGES LEVEL re-keys budgets live (pure accounting),
            # but flipping the physical cache LAYOUT — contiguous <->
            # paged — resizes every cache leaf, which is structural
            raise ValueError(
                "live replan cannot switch the KV-cache layout "
                f"({'paged' if plan.paged else 'contiguous'} -> "
                f"{'paged' if new.paged else 'contiguous'}); "
                "connect() a fresh client with the paged plan instead")
        if new.vector != plan.vector:
            self._apply_vector(new.vector)
            self.transitions.append((None, new.vector))
        self.plan = new
        return new

    # ----- lifecycle ------------------------------------------------------
    def close(self):
        self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __repr__(self):
        v = self.plan.vector
        return (f"ServeClient(executor={self.executor!r}, "
                f"vector=(slots={v.slots}, channels={v.channels}, "
                f"execs={v.execs}), workers={self.plan.n_workers}, "
                f"slots={self.plan.n_slots})")


def connect(cfg, plan: Union[EndpointPlan, Hints, SharingVector, str,
                             None] = None, *,
            params=None, seed: int = 0,
            obs: Optional[Observability] = None,
            faults: Union[FaultPlan, str, None] = None,
            recovery: Optional[RecoveryPolicy] = None,
            plan_repository=None, use_repository: bool = True,
            migrations=None,
            **overrides) -> ServeClient:
    """Connect a serving session: resolve ``plan`` (an ``EndpointPlan``,
    ``Hints``, ``SharingVector``, ``Category``/preset name, or None for
    the default plan; ``overrides`` set/replace plan fields) and return a
    ``ServeClient`` over the executor the plan selects.  ``params``
    defaults to freshly initialized weights (``seed``).  ``obs`` (an
    ``obs.Observability``, e.g. ``obs.enabled_obs()``) turns on the
    flight recorder + metrics registry for every run.  ``faults`` (a
    ``FaultPlan`` or its ``"crash@4.5ms:w0,stall@2ms:w1:1ms"`` grammar)
    injects deterministic failures into every fleet run; ``recovery``
    (a ``serve.RecoveryPolicy``) tunes detection, retry backoff, and
    overload shedding — both need the fleet executor.

    ``migrations`` schedules decode→decode live migrations on every
    fleet run: ``(t_ns, src_worker, dst_worker)`` triples drained at
    their virtual times — the source's live sessions leave as KV
    handoffs and resume on the destination without dropping or
    duplicating a token (DESIGN.md §17).  ``roles="2P+2D"`` (a plan
    field / override) splits the fleet into prefill-only and
    decode-only sub-fleets with the KV handed off after each prefill.

    ``plan_repository`` (DESIGN.md §16) attaches a tuned-plan store
    (``tune.PlanRepository``): ``Hints`` resolution consults its stored
    Pareto-frontier plans before the analytic planner
    (``use_repository=False`` is the explicit escape hatch — attach the
    store for the adaptive controller but resolve analytically), and
    the adaptive controller jumps between its frontier plans instead of
    stepping one sharing axis at a time."""
    if isinstance(plan, Hints) and plan_repository is not None:
        resolved = EndpointPlan.from_hints(
            plan, repository=plan_repository,
            use_repository=use_repository, **overrides)
    else:
        resolved = as_plan(plan, **overrides)
    if params is None:
        params = Model(cfg).init(jax.random.PRNGKey(seed))
    return ServeClient(cfg, params, resolved, obs=obs, faults=faults,
                       recovery=recovery, plan_repository=plan_repository,
                       migrations=migrations)


# connect(..., adaptive=True) is the one-flag spelling of live
# re-planning: the override lands on the plan, and the client attaches a
# core.adapt.Replanner to every run (DESIGN.md §12).  Manual migration is
# client.replan(plan_or_hints); both go through the same apply path.
