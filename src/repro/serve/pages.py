"""Deterministic fixed-size KV-cache page allocator (DESIGN.md §13).

The paper's follow-up ("Lessons Learned on MPI+Threads Communication",
PAPERS.md) locates the sharing win in the LARGE, rarely-saturated
resources — registered memory regions and buffers — while the contended
scheduling resources stay partitioned.  The serving analogue: the KV
cache is by far the largest per-session reservation (``max_len`` rows
per slot today), yet most sessions use a fraction of it.  ``PagePool``
re-founds that reservation on fixed-size pages drawn from a shared
pool, budgeted per *page group* of slots by the fourth ``SharingVector``
axis:

* pages level 1 — every slot holds a dedicated full-length budget
  (``max_pages`` pages each): admission can never defer on memory, and
  the reachable state space is exactly the historical contiguous cache;
* level 2/3 — slots pool budgets in groups of ``level_group_size``;
* level 4 — one fleet-wide pool: maximal packing, admission defers
  (never corrupts) when the pool is dry.

Everything is host-side integer bookkeeping — NumPy tables, no jax —
and fully deterministic: the free list is a min-heap, ``alloc`` always
hands out the lowest-numbered free pages, so the same op sequence
always produces the same page tables (property-tested in
``tests/test_page_pool.py``).
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.endpoints import level_group_size

#: Page-table sentinel for "no page mapped": one past the last valid
#: page id, so device-side scatters drop it (``mode="drop"``) and
#: gathers clip to a real page whose garbage the length mask hides.
def sentinel(n_pages: int) -> int:
    return n_pages


class PagePool:
    """Free-list page allocator with per-group budgets over slots.

    Parameters:
      level: pages sharing level 1..4 (``SharingVector.pages``).
      n_slots: slots served by this pool (page groups partition these).
      max_pages: pages a single sequence can map (``max_len / page_size``).
      total_pages: pool capacity.  Defaults to the dedicated reservation
        ``n_slots * max_pages``; a tighter ``EndpointPlan.page_budget``
        shrinks it (that is the whole point of pooling).

    Invariants (the property-test contract):
      * conservation — ``len(free) + sum(live pages) == total_pages``;
      * no aliasing — live slots own pairwise-disjoint page sets;
      * determinism — identical op sequences yield identical tables;
      * OOM defers — a failed ``alloc`` returns None and mutates nothing;
      * ``regroup`` re-keys budgets only — every live mapping survives.
    """

    def __init__(self, level: int, n_slots: int, max_pages: int, *,
                 total_pages: Optional[int] = None):
        if not 1 <= int(level) <= 4:
            raise ValueError(f"pages level must be in 1..4, got {level!r}")
        if n_slots < 1 or max_pages < 1:
            raise ValueError("n_slots and max_pages must be >= 1")
        self.level = int(level)
        self.n_slots = int(n_slots)
        self.max_pages = int(max_pages)
        self.total_pages = int(total_pages if total_pages is not None
                               else n_slots * max_pages)
        if self.total_pages < 1:
            raise ValueError("total_pages must be >= 1")
        self._free: List[int] = list(range(self.total_pages))
        heapq.heapify(self._free)
        #: slot -> its page ids, in allocation order
        self._owned: Dict[int, List[int]] = {}
        #: pages withheld by an external pressure spike (chaos fabric) —
        #: neither free nor owned by a slot; ``restore`` returns them
        self._seized: List[int] = []
        self._seized_ever = False     # keeps the series once it exists
        self.deferrals = 0            # admission attempts the pool refused
        self.hwm = 0                  # high-water mark of live pages

    # ----- group structure ----------------------------------------------
    @property
    def group_size(self) -> int:
        return level_group_size(self.level, self.n_slots)

    def group_of(self, slot: int) -> int:
        return slot // self.group_size

    @property
    def groups(self) -> int:
        return -(-self.n_slots // self.group_size)

    def group_budget(self, group: int) -> int:
        """Pages group ``group`` may hold live: an even split of the pool
        over groups, by each group's slot share.  At level 1 with the
        default pool this is exactly ``max_pages`` per slot — dedicated
        reservation, admission can never defer."""
        lo = group * self.group_size
        slots_in = max(0, min(self.n_slots, lo + self.group_size) - lo)
        return (self.total_pages * slots_in) // self.n_slots

    def group_live(self, group: int) -> int:
        return sum(len(p) for s, p in self._owned.items()
                   if self.group_of(s) == group)

    # ----- accounting ----------------------------------------------------
    @property
    def live_pages(self) -> int:
        return sum(len(p) for p in self._owned.values())

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pressure(self) -> float:
        """Unavailable-page fraction of the pool (live + seized) — the
        pool-pressure telemetry ``core.adapt.Replanner(paged=True)``
        promotes/demotes on; a chaos-fabric pressure spike registers
        here exactly like organic occupancy."""
        return (self.live_pages + len(self._seized)) / self.total_pages

    # ----- the allocator --------------------------------------------------
    def alloc(self, slot: int, n: int) -> Optional[List[int]]:
        """Reserve ``n`` pages for ``slot``; the lowest-numbered free
        pages, in heap order.  Returns None — state untouched — when the
        slot's group budget or the free list cannot cover the request
        (the caller DEFERS admission; nothing is ever partially
        granted).  A slot allocates once per residency."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range 0..{self.n_slots - 1}")
        if slot in self._owned:
            raise ValueError(f"slot {slot} already holds pages; "
                             f"free it before re-admitting")
        if not 1 <= n <= self.max_pages:
            raise ValueError(f"need 1..{self.max_pages} pages, got {n}")
        g = self.group_of(slot)
        if self.group_live(g) + n > self.group_budget(g) \
                or n > len(self._free):
            self.deferrals += 1
            return None
        pages = [heapq.heappop(self._free) for _ in range(n)]
        self._owned[slot] = pages
        self.hwm = max(self.hwm, self.live_pages)
        return list(pages)

    def free(self, slot: int) -> List[int]:
        """Return every page ``slot`` holds to the free list (retire /
        eviction path).  Freeing an empty slot is a no-op — retire paths
        race benignly with never-admitted slots."""
        pages = self._owned.pop(slot, [])
        for p in pages:
            heapq.heappush(self._free, p)
        return pages

    def pages_of(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    # ----- external pressure (the chaos fabric's page_pressure fault) -----
    @property
    def seized_pages(self) -> int:
        return len(self._seized)

    def seize(self, n: int) -> List[int]:
        """Withhold up to ``n`` FREE pages from the pool (a co-tenant
        spike): the lowest-numbered free pages leave the free list but
        belong to no slot, so admissions defer against the shrunken
        pool while every live mapping is untouched.  -> the seized page
        ids (pass them back through :meth:`restore`)."""
        n = max(0, min(int(n), len(self._free)))
        taken = [heapq.heappop(self._free) for _ in range(n)]
        self._seized.extend(taken)
        if taken:
            self._seized_ever = True
        return taken

    def restore(self, pages: List[int]) -> None:
        """Return pages taken by :meth:`seize` to the free list."""
        for p in pages:
            self._seized.remove(p)
            heapq.heappush(self._free, p)

    def table(self, slot: int) -> np.ndarray:
        """The slot's dense page table: ``(max_pages,)`` int32, owned
        pages first (logical page j of the sequence lives in physical
        page ``table[j]``), sentinel-padded."""
        t = np.full((self.max_pages,), sentinel(self.total_pages),
                    np.int32)
        pages = self._owned.get(slot, [])
        t[:len(pages)] = pages
        return t

    # ----- observability --------------------------------------------------
    def publish_metrics(self, registry, **labels) -> None:
        """Publish this pool's counters into an ``obs.MetricsRegistry``
        under ``labels`` (callers pass ``axis="pages", worker=w`` — the
        paper-style per-resource counter convention, DESIGN.md §14)."""
        registry.counter("pages.deferrals", **labels).set_total(
            self.deferrals)
        registry.gauge("pages.hwm", **labels).set(self.hwm)
        registry.gauge("pages.live", **labels).set(self.live_pages)
        if self._seized or self._seized_ever:
            # fault-only series: fault-free runs keep today's exact
            # metric-series census (bit-identical exports)
            registry.gauge("pages.seized", **labels).set(
                len(self._seized))
        registry.gauge("pages.pressure", **labels).set(self.pressure())

    # ----- live migration -------------------------------------------------
    def regroup(self, level: int) -> "PagePool":
        """Re-key the budget groups to a new pages level IN PLACE (the
        ``SlotPool.regroup`` convention).  Pure accounting: no page
        moves, no mapping dropped — live allocations simply answer to
        the new group budgets from now on.  A shrink below what a group
        already holds only gates FUTURE allocs."""
        if not 1 <= int(level) <= 4:
            raise ValueError(f"pages level must be in 1..4, got {level!r}")
        self.level = int(level)
        return self
