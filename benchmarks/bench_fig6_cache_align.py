"""Paper Fig. 6: independent 2-byte buffers with and without 64-byte cache
alignment (unaligned buffers land on one line -> serialized DMA reads)."""

from repro.core import build_ctx_shared
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES, BufferConfig
from benchmarks.common import row


def main():
    m = build_ctx_shared(16, 1)
    feats = ALL_FEATURES.without("inline")
    for label, bufs in [("aligned", BufferConfig.aligned(16)),
                        ("unaligned", BufferConfig.unaligned(16, 2))]:
        r = message_rate(m, features=feats, buffers=bufs,
                         msgs_per_thread=2048)
        row(f"fig6_{label}", 1.0 / r.rate_mmps, f"{r.rate_mmps:.1f}Mmsgs/s")


if __name__ == "__main__":
    main()
