"""Observability overhead bench (DESIGN.md §14): the flight recorder +
metrics registry against the zero-overhead-when-off contract.

The obs fabric rides inside the virtual-time event loop, so the FIRST
claim is exact, not statistical: with observability enabled the fleet's
virtual schedule is BIT-IDENTICAL to the disabled run — tracing reads
timestamps, it never advances them.  The bench runs the canonical
deterministic bursty trace (8 workers, ``shared_dynamic``) three ways —
obs defaulted off, obs explicitly the no-op bundle, obs fully enabled —
and pins:

* ``overhead_disabled_frac`` / ``overhead_enabled_frac``: relative
  virtual-throughput deltas vs the defaulted run.  Deterministically 0.0
  (gated near-exactly by ``check_regression``) — the paper-style budget
  bands from ISSUE #7 (disabled < 1%, enabled < 5%) hold with margin ∞;
* structural trace/metric volumes (events, series) — a silent drop in
  coverage fails the gate the same way a perf slide would;
* the exported trace passes ``obs.validate_trace`` (span conservation,
  per-track serialization);
* host wall time per mode (min-of-repeats, informational only — CI
  hardware varies) plus a micro-bench of the per-event no-op guard, the
  cost every un-instrumented run pays per emission site.

  PYTHONPATH=src:. python -m benchmarks.bench_obs
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import row, write_bench_json
from repro.core.plan import SharingVector
from repro.obs import NOOP_OBS, enabled_obs, validate_trace
from repro.serve.fabric import build_sim_fleet, canonical_bursty_trace

N_WORKERS = 8
N_SLOTS = 4
VECTOR = SharingVector(slots=2, channels=2, execs=2)
REPEAT = 5


def run_once(trace, obs=None):
    router = build_sim_fleet(N_WORKERS, VECTOR, n_slots=N_SLOTS, obs=obs)
    rep = router.run(trace)
    assert rep.n_completed == rep.n_arrivals, rep.n_completed
    return rep


def timed_min(trace, obs_factory):
    """Min-of-REPEAT host wall seconds (min, not mean: the estimator
    robust to scheduler noise on shared CI hosts)."""
    best, rep = float("inf"), None
    for _ in range(REPEAT):
        obs = obs_factory()
        t0 = time.perf_counter()
        rep = run_once(trace, obs=obs)
        best = min(best, time.perf_counter() - t0)
    return rep, best, obs


def report_fingerprint(rep) -> tuple:
    """Every virtual-time quantity the schedule determines; equal
    fingerprints == bit-identical schedules."""
    return (rep.makespan_ns, rep.total_new_tokens, rep.n_completed,
            rep.occupancy, rep.lock_wait_ns,
            tuple(sorted(rep.latency_ns.items())),
            tuple(rep.per_worker_tokens))


def guard_cost_ns(n: int = 200_000) -> float:
    """Per-call cost of the no-op emission guard — the entire price a
    disabled run pays at each instrumentation site."""
    rec = NOOP_OBS.recorder
    t0 = time.perf_counter()
    for _ in range(n):
        if rec.enabled:
            rec.instant(1, 0, "x", 0.0)
    dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n):
        pass
    return max(0.0, (dt - (time.perf_counter() - t0)) / n * 1e9)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    trace = canonical_bursty_trace()
    rep_off, wall_off, _ = timed_min(trace, lambda: None)
    rep_dis, wall_dis, _ = timed_min(trace, lambda: NOOP_OBS)
    rep_on, wall_on, obs = timed_min(trace, enabled_obs)

    fp = report_fingerprint(rep_off)
    identical = (report_fingerprint(rep_dis) == fp
                 and report_fingerprint(rep_on) == fp)
    # virtual throughput is THE gated quantity: deterministic, so the
    # overhead fractions are exactly 0.0 on every host
    tps = rep_off.tok_per_s
    dis_frac = abs(rep_dis.tok_per_s - tps) / tps
    on_frac = abs(rep_on.tok_per_s - tps) / tps

    doc = obs.recorder.to_chrome()
    problems = validate_trace(doc)
    n_events = len(doc["traceEvents"])
    n_series = len(obs.metrics.names())
    guard_ns = guard_cost_ns()

    ok = (identical and not problems and dis_frac <= 0.01
          and on_frac <= 0.05 and n_events > 0 and n_series > 0)
    rows = [{"config": {
        "mode": "overhead", "workers": N_WORKERS, "n_slots": N_SLOTS,
        "vector": VECTOR.label, "trace": "canonical_bursty"},
        "metrics": {
            "tok_per_s": tps,
            "overhead_disabled_frac": dis_frac,
            "overhead_enabled_frac": on_frac,
            "trace_events": n_events,
            "metric_series": n_series,
            "trace_valid": not problems,
            "identical_reports": identical,
            "tokens": rep_off.total_new_tokens,
            "completed": rep_off.n_completed,
            "wall_off_ms": wall_off * 1e3,
            "wall_disabled_ms": wall_dis * 1e3,
            "wall_enabled_ms": wall_on * 1e3,
            "guard_ns_per_event": guard_ns,
            "acceptance": ok}}]
    row("obs_overhead", 1e3 / max(tps, 1e-9) * 1e6,
        f"disabled={dis_frac * 100:.2f}%|enabled={on_frac * 100:.2f}%"
        f"|{n_events}events|{n_series}series"
        f"|wall {wall_off * 1e3:.1f}->{wall_on * 1e3:.1f}ms"
        f"|guard={guard_ns:.0f}ns"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (identical, problems[:3], dis_frac, on_frac)

    write_bench_json("obs", rows, out=args.out)


if __name__ == "__main__":
    main()
