"""Paper Fig. 14: 5-point stencil halo exchange across hybrid rank/thread
splits (16.1 / 4.4 / 1.16) x endpoint categories.

The stencil compute runs for real in JAX (1-D partitioned grid, jnp.roll
halo semantics); the halo messages per iteration are 2 per rank boundary
(the paper's footnote: intranode IB still crosses the NIC), and their cost
comes from the calibrated ibsim with the hybrid endpoint layout
(per-rank CTX sets via build_hybrid)."""

import jax
import jax.numpy as jnp

from repro.core import paper_categories
from repro.core.endpoints import build_hybrid
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import CONSERVATIVE
from benchmarks.common import row, timed

GRID = 1024
SPLITS = [(16, 1), (4, 4), (1, 16)]


def _stencil_pass():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (GRID, GRID), jnp.float32)

    @jax.jit
    def step(g):
        return 0.25 * (jnp.roll(g, 1, 0) + jnp.roll(g, -1, 0)
                       + jnp.roll(g, 1, 1) + jnp.roll(g, -1, 1)) - g

    out = step(g)
    return float(jnp.sum(out))


def main():
    _, dt = timed(_stencil_pass, repeat=2)
    row("fig14_stencil_compute", dt * 1e6, f"grid={GRID}")

    for cat in paper_categories():
        for p, t in SPLITS:
            m = build_hybrid(p, t, cat)
            r = message_rate(m, features=CONSERVATIVE, msgs_per_thread=2048)
            u = m.usage
            # messages per stencil iteration: 2 per rank (both neighbors)
            msgs_per_iter = 2 * p
            row(f"fig14_{cat.value}_{p}.{t}", 1.0 / r.rate_mmps,
                f"{r.rate_mmps:.1f}Mmsgs/s|msgs/iter={msgs_per_iter}"
                f"|qps={u.qps}|cqs={u.cqs}|uars={u.uars}|uuars={u.uuars}")


if __name__ == "__main__":
    main()
