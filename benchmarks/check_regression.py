"""Bench regression gate: freshly emitted BENCH_*.json vs committed
baselines (DESIGN.md §12).

The serving benches emit machine-readable rows
(``benchmarks.common.write_bench_json``); this gate compares them
against the baselines committed under ``benchmarks/baselines/`` and
fails (exit 1) on regression, so CI catches a perf or footprint slide
the moment it lands instead of three PRs later.

Comparison policy — rows matched by their full ``config`` dict:

* **virtual-time benches** (fabric, plan, adapt) are deterministic pure
  arithmetic: ``tok_per_s`` and ``p99_ms`` gate inside a tolerance band
  (default ±10%, regressions only — a fresh IMPROVEMENT never fails),
  footprint fields near-exactly;
* **wall-clock benches** (serve) vary with host hardware, so their
  ``tok_per_s`` gates only when ``--wall-tolerance`` is set (> 0);
  their *structural* metrics — tokens, decode steps, host syncs,
  dispatch/compile counts — are hardware-independent and gate tightly;
* a baseline row MISSING from the fresh emission fails (coverage
  regression); fresh rows without a baseline pass with a note (new
  configs are fine until ``--update`` re-baselines);
* acceptance flags must stay truthy.

Usage (CI runs exactly this after the bench step):

  PYTHONPATH=src:. python -m benchmarks.check_regression \
      --fresh-dir bench-artifacts
  # re-baseline after an intentional perf change:
  PYTHONPATH=src:. python -m benchmarks.check_regression \
      --fresh-dir bench-artifacts --update
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE_DIR = os.path.join(HERE, "baselines")

#: bench name -> deterministic in virtual time (gate perf metrics) or
#: wall-clock (gate structure only, unless --wall-tolerance).  "obs"
#: qualifies: its gated quantities (virtual throughput, trace/series
#: volumes, the 0.0 overhead fractions) are all schedule-determined —
#: only its ungated wall_*_ms fields touch the host clock.
VIRTUAL_TIME = {"fabric", "plan", "adapt", "paged", "obs", "faults",
                "tune", "disagg"}

#: metric -> (direction, kind).  direction: which way is WORSE ("either"
#: gates both ways).  kind "perf" gates per the bench's time domain;
#: "struct" and "exact" always gate, within --struct-tolerance, in the
#: worse direction only; "flag" must stay truthy.
GATES: Dict[str, Tuple[str, str]] = {
    "tok_per_s": ("lower", "perf"),
    "p50_ms": ("higher", "perf"),
    "p99_ms": ("higher", "perf"),
    "mean_footprint": ("higher", "exact"),
    "footprint": ("higher", "exact"),
    "page_hwm_frac": ("higher", "exact"),
    "page_deferrals": ("higher", "struct"),
    "tokens": ("either", "struct"),
    "completed": ("either", "struct"),
    "decode_steps": ("either", "struct"),
    "decode_calls": ("either", "struct"),
    "prefill_calls": ("either", "struct"),
    "host_syncs": ("either", "struct"),
    "host_syncs_per_token": ("higher", "struct"),
    "compiles_admit": ("higher", "struct"),
    "compiles_prefill_exact": ("higher", "struct"),
    "compiles_horizon": ("higher", "struct"),
    # observability (bench_obs): virtual-throughput overhead bands —
    # deterministically 0.0, so any drift is a real zero-overhead-when-
    # off violation — and trace/metric coverage volumes
    "overhead_disabled_frac": ("higher", "struct"),
    "overhead_enabled_frac": ("higher", "struct"),
    "trace_events": ("either", "struct"),
    "metric_series": ("either", "struct"),
    # chaos fabric (bench_faults): deterministic fault/recovery ledgers
    # — any drift in detection, retry, or shed behaviour is a real
    # semantic change — plus the kill-1-of-4 throughput floor
    "vs_healthy": ("lower", "exact"),
    "detections": ("either", "struct"),
    "retries": ("either", "struct"),
    "recovered": ("either", "struct"),
    "failed": ("higher", "struct"),
    "duplicates": ("higher", "struct"),
    "recovery_latency_ms": ("higher", "perf"),
    "shed_frac": ("either", "struct"),
    "shed_frac_p0": ("either", "struct"),
    "shed_frac_p2": ("either", "struct"),
    # prefill/decode disaggregation (bench_disagg): deterministic
    # handoff/migration ledgers — a drift in KV moved or handoff counts
    # is a topology-semantics change — plus the throughput floor vs the
    # co-located fleet and the decode-tail improvement
    "handoffs": ("either", "struct"),
    "kv_tokens_moved": ("either", "struct"),
    "kv_bytes_moved": ("either", "struct"),
    "migrations": ("either", "struct"),
    "vs_colocated": ("lower", "exact"),
    "decode_p99_ms": ("higher", "perf"),
    "trace_valid": ("flag", "flag"),
    "identical_reports": ("flag", "flag"),
    "acceptance": ("flag", "flag"),
    # plan-space auto-tuner (bench_tune): deterministic search ledgers —
    # the eval budget actually consumed and the frontier's size are pure
    # functions of (space, driver, seed), and the same-seed rerun must
    # stay byte-reproducible
    "evals": ("either", "struct"),
    "frontier_size": ("either", "struct"),
    "vs_best_diagonal": ("lower", "exact"),
    "footprint_vs_best_diagonal": ("higher", "exact"),
    "reproducible": ("flag", "flag"),
    "sqlite_identical": ("flag", "flag"),
}


def _key(row: dict) -> str:
    return json.dumps(row.get("config", {}), sort_keys=True)


def _load(path: str) -> Dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    return {_key(r): r.get("metrics", {}) for r in data.get("rows", [])}


def _violates(direction: str, base: float, fresh: float,
              tol: float) -> bool:
    """True when ``fresh`` regresses past the tolerance band."""
    if direction == "either":
        return abs(fresh - base) > tol * max(abs(base), 1e-12) + 1e-9
    scale = max(abs(base), 1e-12)
    if direction == "lower":          # lower is worse (throughput)
        return fresh < base - tol * scale - 1e-9
    return fresh > base + tol * scale + 1e-9      # higher is worse


def compare_rows(name: str, base: dict, fresh: dict, *,
                 tolerance: float, wall_tolerance: float,
                 struct_tolerance: float) -> List[str]:
    """-> list of violation strings for one (baseline, fresh) row pair."""
    virtual = name in VIRTUAL_TIME
    problems = []
    for metric, (direction, kind) in GATES.items():
        if metric not in base or metric not in fresh:
            continue
        b, f = base[metric], fresh[metric]
        if kind == "flag":
            if bool(b) and not bool(f):
                problems.append(f"{metric}: acceptance flipped "
                                f"{b!r} -> {f!r}")
            continue
        if kind == "perf":
            tol = tolerance if virtual else wall_tolerance
            if tol <= 0:
                continue              # wall-clock perf ungated by default
        else:
            tol = struct_tolerance
        if _violates(direction, float(b), float(f), tol):
            problems.append(f"{metric}: baseline {b:.6g} -> fresh "
                            f"{f:.6g} (worse-direction={direction}, "
                            f"tol={tol:g})")
    return problems


def compare_files(name: str, base_path: str, fresh_path: str,
                  **tols) -> Tuple[List[str], int, int]:
    """-> (violations, rows compared, fresh-only rows)."""
    base, fresh = _load(base_path), _load(fresh_path)
    violations = []
    for key, metrics in base.items():
        if key not in fresh:
            violations.append(f"row missing from fresh emission: {key}")
            continue
        for p in compare_rows(name, metrics, fresh[key], **tols):
            violations.append(f"{key}: {p}")
    return violations, len(base.keys() & fresh.keys()), \
        len(fresh.keys() - base.keys())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--fresh-dir",
                    default=os.environ.get("BENCH_OUT_DIR", "."))
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative band for virtual-time perf metrics "
                         "(tok_per_s/p50/p99; regressions only)")
    ap.add_argument("--wall-tolerance", type=float, default=0.0,
                    help="relative band for WALL-CLOCK perf metrics; 0 "
                         "(default) skips them — CI hardware varies")
    ap.add_argument("--struct-tolerance", type=float, default=0.02,
                    help="relative band for structural/footprint "
                         "metrics (token counts, sync counts, compile "
                         "counts, footprint fractions)")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh files over the baselines "
                         "instead of comparing")
    args = ap.parse_args(argv)

    if args.update:
        # before the baseline guard: --update is also the bootstrap path
        # into a missing or empty baseline dir
        os.makedirs(args.baseline_dir, exist_ok=True)
        for name in sorted(
                f for f in os.listdir(args.fresh_dir)
                if f.startswith("BENCH_") and f.endswith(".json")):
            shutil.copy(os.path.join(args.fresh_dir, name),
                        os.path.join(args.baseline_dir, name))
            print(f"re-baselined {name}")
        return 0

    names = sorted(
        f[len("BENCH_"):-len(".json")]
        for f in os.listdir(args.baseline_dir)
        if f.startswith("BENCH_") and f.endswith(".json"))
    if not names:
        print(f"no baselines under {args.baseline_dir}", file=sys.stderr)
        return 2

    tols = dict(tolerance=args.tolerance,
                wall_tolerance=args.wall_tolerance,
                struct_tolerance=args.struct_tolerance)
    failed = False
    for name in names:
        base_path = os.path.join(args.baseline_dir, f"BENCH_{name}.json")
        fresh_path = os.path.join(args.fresh_dir, f"BENCH_{name}.json")
        if not os.path.exists(fresh_path):
            print(f"FAIL {name}: fresh {fresh_path} not found "
                  f"(bench not run?)")
            failed = True
            continue
        violations, compared, fresh_only = compare_files(
            name, base_path, fresh_path, **tols)
        domain = "virtual-time" if name in VIRTUAL_TIME else "wall-clock"
        if violations:
            failed = True
            print(f"FAIL {name} ({domain}, {compared} rows):")
            for v in violations:
                print(f"  {v}")
        else:
            extra = f", {fresh_only} new" if fresh_only else ""
            print(f"PASS {name} ({domain}, {compared} rows{extra})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
