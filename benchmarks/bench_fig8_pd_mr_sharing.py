"""Paper Fig. 8: PD / MR sharing — no data-path cost (protection checks run
on the NIC; the MR is a registration object), so throughput is flat and
only the object counts change."""

import dataclasses

from repro.core import build_ctx_shared
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES
from benchmarks.common import row


def main():
    for ways in (1, 2, 4, 8, 16):
        m = build_ctx_shared(16, 16)
        # PD/MR are namespace objects: sharing changes accounting only
        usage = dataclasses.replace(m.usage, pds=max(1, 16 // ways),
                                    mrs=max(1, 16 // ways))
        m = dataclasses.replace(m, usage=usage,
                                label=f"pd_mr_{ways}way")
        r = message_rate(m, features=ALL_FEATURES, msgs_per_thread=2048)
        row(f"fig8_pdmr{ways}way", 1.0 / r.rate_mmps,
            f"{r.rate_mmps:.1f}Mmsgs/s|pds={usage.pds}|mrs={usage.mrs}")


if __name__ == "__main__":
    main()
