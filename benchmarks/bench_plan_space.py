"""Plan-space sweep (DESIGN.md §11): on- and off-diagonal SharingVectors
on the canonical bursty trace.

The paper's Table-1 headline — the scalable middle matches dedicated-path
performance at a fraction of the resources — required sharing *different
resource types at different levels* (dedicated QPs, k-way-shared CQs,
fully shared PD/MR).  The old scalar ``Category`` could only sweep the
diagonal of that space; this bench walks the per-resource plan space the
``EndpointPlan`` redesign opens: every diagonal level plus the
off-diagonal points (slots level != channels level) on an 8-worker
virtual fleet (``SimWorker``: scheduling only, host-milliseconds).

The acceptance row restates the paper's claim for serving: the
off-diagonal plan (dedicated slots, 4-way-shared channels, one shared
executable set) keeps >= 0.9x the BEST diagonal's throughput at <= half
its plan footprint — same performance, a fraction of the resources, and
a point no ``Category`` could name.

  PYTHONPATH=src:. python -m benchmarks.bench_plan_space
"""

from __future__ import annotations

import argparse
import itertools

from benchmarks.common import row, write_bench_json
from repro.core.plan import SharingVector
from repro.tune import bench_metrics, evaluate_vector

N_WORKERS = 8
N_SLOTS = 4

# the four diagonals (the old Category space)...
DIAGONALS = [SharingVector.diagonal(level) for level in (1, 2, 3, 4)]
# ...and the newly reachable off-diagonal points: dedicated or pairwise
# slots under progressively wider channel sharing, executables shared
OFF_DIAGONAL = [SharingVector(slots=s, channels=c, execs=4)
                for s, c in itertools.product((1, 2), (2, 3, 4))
                if s != c]
# THE acceptance candidate: dedicated slots, 4-way-shared channels
CANDIDATE = SharingVector(slots=1, channels=3, execs=4)


def _label(v: SharingVector) -> str:
    return v.label


def run_one(vector: SharingVector, trace="canonical_bursty"):
    """Measure one vector through THE shared sim-evaluation loop
    (``tune.evaluate`` — the tuner's evaluator, DESIGN.md §16)."""
    m = evaluate_vector(vector, trace, n_workers=N_WORKERS,
                        n_slots=N_SLOTS)
    assert m.completed == m.n_arrivals, (vector, m.completed)
    return m


def metrics_of(vector: SharingVector, m) -> dict:
    return bench_metrics(vector, m, n_workers=N_WORKERS, n_slots=N_SLOTS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    rows, results = [], {}
    for vector in DIAGONALS + OFF_DIAGONAL:
        m = metrics_of(vector, run_one(vector))
        results[vector] = m
        rows.append({"config": {
            "slots_level": vector.slots, "channels_level": vector.channels,
            "execs_level": vector.execs, "workers": N_WORKERS,
            "n_slots": N_SLOTS, "trace": "canonical_bursty"},
            "metrics": m})
        kind = "diag" if vector.is_diagonal else "off"
        row(f"plan_{kind}_{_label(vector)}",
            1e3 / max(m["tok_per_s"], 1e-9) * 1e6,
            f"{m['tok_per_s']:.0f}tok/s|p99={m['p99_ms']:.2f}ms"
            f"|occ={m['occupancy']:.2f}"
            f"|footprint={m['footprint'] * 100:.1f}%")

    # acceptance: the off-diagonal candidate vs the BEST diagonal
    best = max((v for v in DIAGONALS),
               key=lambda v: results[v]["tok_per_s"])
    cand = results[CANDIDATE]
    ratio = cand["tok_per_s"] / results[best]["tok_per_s"]
    foot = cand["footprint"] / results[best]["footprint"]
    ok = ratio >= 0.9 and foot <= 0.5
    rows.append({"config": {
        "slots_level": CANDIDATE.slots,
        "channels_level": CANDIDATE.channels,
        "execs_level": CANDIDATE.execs, "workers": N_WORKERS,
        "n_slots": N_SLOTS, "trace": "canonical_bursty",
        "baseline": f"diagonal_L{best.slots}"},
        "metrics": {**cand, "vs_best_diagonal": ratio,
                    "footprint_vs_best_diagonal": foot,
                    "acceptance": ok}})
    row(f"plan_acceptance_{_label(CANDIDATE)}",
        1e3 / max(cand["tok_per_s"], 1e-9) * 1e6,
        f"vs_best_diag={ratio:.3f}x|footprint={foot * 100:.1f}%"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (ratio, foot)

    write_bench_json("plan", rows, out=args.out)


if __name__ == "__main__":
    main()
