"""Chaos fabric: recovery latency, kill-1-of-4 throughput floor, and
overload shedding under 4x pressure (DESIGN.md §15).

All rows run the virtual-time sim fleet — deterministic pure arithmetic,
so every metric (including the throughput ratios) gates tightly in
``check_regression.py``.

Rows:

* ``faults_crash_recovery`` — the canonical w0 crash on the canonical
  bursty trace: outage-to-detection latency, retries, recovered counts.
* ``faults_kill1of4`` — the headline robustness claim: killing 1 of 4
  workers mid-run keeps >= 0.70x the healthy fleet's throughput with
  ZERO tokens lost or duplicated (the ``acceptance`` flag).
* ``faults_chaos`` — all four fault kinds on one paged run (the golden
  chaos scenario): request conservation under compound failures.
* ``faults_overload_4x`` — the canonical trace time-compressed 4x with
  a finite shed capacity: shed fraction by priority tier, and the
  never-accepted-then-dropped invariant (accepted == completed).

  PYTHONPATH=src:. python -m benchmarks.bench_faults
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks.common import row, write_bench_json
from repro.core.plan import SharingVector
from repro.serve.fabric import (build_sim_fleet, canonical_bursty_trace,
                                canonical_chaos_plan,
                                canonical_crash_plan,
                                canonical_faulted_trace)
from repro.serve.recovery import RecoveryPolicy

VEC = SharingVector.diagonal(2)
N_WORKERS = 4


def _run(faults=None, recovery=None, trace=None, **kw):
    router = build_sim_fleet(N_WORKERS, VEC, faults=faults,
                             recovery=recovery, **kw)
    return router.run(canonical_bursty_trace() if trace is None
                      else trace)


def _tokens(rep):
    return {c.rid: c.new_tokens for c in rep.completions}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    rows = []
    healthy = _run()

    # --- canonical crash: detection + recovery latency ------------------
    rep = _run(faults=canonical_crash_plan())
    lat_ms = max(rep.recovery_latency_ns) / 1e6 \
        if rep.recovery_latency_ns else 0.0
    rows.append({"config": {"scenario": "crash_recovery",
                            "faults": canonical_crash_plan().describe(),
                            "workers": N_WORKERS},
                 "metrics": {
                     "tok_per_s": rep.tok_per_s,
                     "tokens": rep.total_new_tokens,
                     "completed": rep.n_completed,
                     "detections": rep.detections,
                     "retries": rep.retries,
                     "recovered": len(rep.recovered),
                     "failed": len(rep.failed),
                     "duplicates": rep.duplicate_completions,
                     "recovery_latency_ms": lat_ms}})
    row("faults_crash_recovery", lat_ms * 1e3,
        f"detect={lat_ms:.2f}ms|retries={rep.retries}"
        f"|recovered={len(rep.recovered)}|failed={len(rep.failed)}")

    # --- kill 1 of 4: throughput floor + zero token loss ----------------
    vs_healthy = rep.tok_per_s / healthy.tok_per_s
    conserved = _tokens(rep) == _tokens(healthy) \
        and rep.duplicate_completions == 0
    ok = vs_healthy >= 0.70 and conserved
    rows.append({"config": {"scenario": "kill1of4",
                            "faults": canonical_crash_plan().describe(),
                            "workers": N_WORKERS},
                 "metrics": {
                     "tok_per_s": rep.tok_per_s,
                     "vs_healthy": vs_healthy,
                     "tokens": rep.total_new_tokens,
                     "completed": rep.n_completed,
                     "duplicates": rep.duplicate_completions,
                     "acceptance": ok}})
    row("faults_kill1of4", 1e3 / max(rep.tok_per_s, 1e-9) * 1e6,
        f"vs_healthy={vs_healthy:.3f}x|conserved={conserved}"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (vs_healthy, conserved)

    # --- compound chaos (the golden scenario), paged --------------------
    trace = canonical_faulted_trace()
    chaos = _run(faults=canonical_chaos_plan(), trace=trace,
                 page_size=16)
    base = _run(trace=trace, page_size=16)
    chaos_ok = _tokens(chaos) == _tokens(base) \
        and chaos.duplicate_completions == 0 and not chaos.failed
    rows.append({"config": {"scenario": "chaos",
                            "faults": canonical_chaos_plan().describe(),
                            "workers": N_WORKERS, "page_size": 16},
                 "metrics": {
                     "tok_per_s": chaos.tok_per_s,
                     "tokens": chaos.total_new_tokens,
                     "completed": chaos.n_completed,
                     "detections": chaos.detections,
                     "retries": chaos.retries,
                     "recovered": len(chaos.recovered),
                     "failed": len(chaos.failed),
                     "duplicates": chaos.duplicate_completions,
                     "acceptance": chaos_ok}})
    row("faults_chaos", 1e3 / max(chaos.tok_per_s, 1e-9) * 1e6,
        f"faults={chaos.faults_injected}|detect={chaos.detections}"
        f"|recovered={len(chaos.recovered)}"
        f"|acceptance={'PASS' if chaos_ok else 'FAIL'}")
    assert chaos_ok

    # --- 4x overload: shed fraction, lowest tier first ------------------
    squeezed = [dataclasses.replace(a, t_ns=a.t_ns / 4.0,
                                    deadline_ns=-1.0)
                for a in canonical_faulted_trace()]
    pol = RecoveryPolicy(shed_capacity=12)
    rep = _run(recovery=pol, trace=squeezed)
    n = len(squeezed)
    shed_frac = rep.n_shed / n
    pri = {a.rid: a.priority for a in squeezed}
    shed_rids = {rid for rid, _, _ in rep.shed}
    tier_frac = {}
    for p in (0, 1, 2):
        tier = [a.rid for a in squeezed if pri[a.rid] == p]
        tier_frac[p] = len([r for r in tier if r in shed_rids]) \
            / max(1, len(tier))
    # never accepted-then-dropped: every accepted arrival completed
    invariant = rep.n_arrivals == rep.n_completed \
        and not (shed_rids & {c.rid for c in rep.completions})
    shed_ok = invariant and 0.0 < shed_frac < 1.0 \
        and tier_frac[0] >= tier_frac[2]
    rows.append({"config": {"scenario": "overload_4x",
                            "shed_capacity": pol.shed_capacity,
                            "workers": N_WORKERS},
                 "metrics": {
                     "tok_per_s": rep.tok_per_s,
                     "completed": rep.n_completed,
                     "shed_frac": shed_frac,
                     "shed_frac_p0": tier_frac[0],
                     "shed_frac_p2": tier_frac[2],
                     "acceptance": shed_ok}})
    row("faults_overload_4x", 1e3 / max(rep.tok_per_s, 1e-9) * 1e6,
        f"shed={shed_frac:.2f}|p0={tier_frac[0]:.2f}"
        f"|p2={tier_frac[2]:.2f}"
        f"|acceptance={'PASS' if shed_ok else 'FAIL'}")
    assert shed_ok, (shed_frac, tier_frac, invariant)

    write_bench_json("faults", rows, out=args.out)


if __name__ == "__main__":
    main()
