"""Paper Table I: bytes used by mlx5 Verbs resources + endpoint memory —
and the serving analogue, the ACTUAL reserved KV-cache bytes of the
smoke deployment under the contiguous vs paged layouts.

Table I's point is that endpoint memory is dominated by one large,
rarely-saturated resource (the context + registered regions).  The
serving stack's equivalent is the KV cache: the contiguous layout pins
``n_slots x max_len`` rows up front, while the paged layout
(DESIGN.md §13) reserves a page pool the plan budgets.  The bytes below
are measured off real ``Model.init_cache`` buffers (every leaf of the
cache pytree, page tables included), not estimated.
"""

import jax

from benchmarks.common import row
from repro.core import resources as R

N_SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 16
MAX_PAGES = MAX_LEN // PAGE_SIZE
POOL_FRAC = 0.4


def _cache_bytes(model, **kw) -> int:
    cache = model.init_cache(N_SLOTS, MAX_LEN, per_slot=True, **kw)
    return sum(a.nbytes for a in jax.tree.leaves(cache))


def main():
    for name, b in [("ctx", R.CTX_BYTES), ("pd", R.PD_BYTES),
                    ("mr", R.MR_BYTES), ("qp", R.QP_BYTES),
                    ("cq", R.CQ_BYTES),
                    ("endpoint_total", R.ENDPOINT_BYTES)]:
        row(f"table1_{name}_bytes", 0.0, str(b))
    row("table1_ctx_share_pct", 0.0,
        f"{R.CTX_BYTES / R.ENDPOINT_BYTES * 100:.1f}")

    # ----- the serving analogue: reserved KV-cache bytes -----------------
    from repro.configs import get_smoke_config
    from repro.models.model import Model
    model = Model(get_smoke_config("qwen2-0.5b"))
    contiguous = _cache_bytes(model)
    dedicated = N_SLOTS * MAX_PAGES
    paged_p1 = _cache_bytes(model, page_size=PAGE_SIZE,
                            n_pages=dedicated)
    pool = max(1, int(POOL_FRAC * dedicated))
    paged_p4 = _cache_bytes(model, page_size=PAGE_SIZE, n_pages=pool)
    row("table1_kv_contiguous_bytes", 0.0, str(contiguous))
    row("table1_kv_paged_dedicated_bytes", 0.0,
        f"{paged_p1}|{paged_p1 / contiguous * 100:.1f}%of_contiguous")
    row("table1_kv_paged_pooled_bytes", 0.0,
        f"{paged_p4}|budget={pool}of{dedicated}pages"
        f"|{paged_p4 / contiguous * 100:.1f}%of_contiguous")


if __name__ == "__main__":
    main()
