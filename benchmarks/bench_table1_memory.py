"""Paper Table I: bytes used by mlx5 Verbs resources + endpoint memory."""

from repro.core import resources as R
from benchmarks.common import row


def main():
    for name, b in [("ctx", R.CTX_BYTES), ("pd", R.PD_BYTES),
                    ("mr", R.MR_BYTES), ("qp", R.QP_BYTES),
                    ("cq", R.CQ_BYTES),
                    ("endpoint_total", R.ENDPOINT_BYTES)]:
        row(f"table1_{name}_bytes", 0.0, str(b))
    row("table1_ctx_share_pct", 0.0,
        f"{R.CTX_BYTES / R.ENDPOINT_BYTES * 100:.1f}")


if __name__ == "__main__":
    main()
