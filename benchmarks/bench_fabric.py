"""Fleet-level category sweep: the paper's endpoint tradeoff applied to a
worker fleet behind the fabric router (DESIGN.md §9).

Sweeps dispatch category x worker count x traffic shape on the virtual-
time fleet (SimWorker: pure scheduling, no model — the whole sweep is
host-milliseconds) and reports tokens/s, p50/p99 request latency, pool
occupancy, dispatch fairness, queue-lock wait, and the fleet's aggregate
endpoint footprint relative to dedicated-per-worker.

The acceptance row (`fabric_acceptance`) pins the headline claim on the
canonical deterministic bursty trace with 8 workers: every k-way-shared
dispatch category keeps >= 0.9x the throughput of dedicated-per-worker
queues at <= half the aggregate endpoint footprint.

  PYTHONPATH=src:. python -m benchmarks.bench_fabric
"""

from __future__ import annotations

import argparse

from benchmarks.common import row, write_bench_json
from repro.core.endpoints import Category
from repro.serve.fabric import (TRAFFIC_SHAPES, build_sim_fleet,
                                canonical_bursty_trace)

# dedicated / k-way-shared middle / one shared funnel (paper Section VI)
CATEGORIES = (Category.MPI_EVERYWHERE, Category.SHARED_DYNAMIC,
              Category.STATIC, Category.MPI_THREADS)
WORKER_COUNTS = (2, 4, 8)
TRAFFICS = ("poisson", "bursty", "session")


def run_one(category: Category, n_workers: int, trace, *,
            placement: str = "round_robin", n_slots: int = 4):
    router = build_sim_fleet(n_workers, category, n_slots=n_slots,
                             placement=placement)
    rep = router.run(trace)
    assert rep.n_completed == rep.n_arrivals, \
        (category, n_workers, rep.n_completed, rep.n_arrivals)
    return rep


def metrics_of(rep) -> dict:
    return {
        "tok_per_s": rep.tok_per_s,
        "p50_ms": rep.latency_percentile(0.5) / 1e6,
        "p99_ms": rep.latency_percentile(0.99) / 1e6,
        "occupancy": rep.occupancy,
        "fairness": rep.fairness,
        "lock_wait_ns": rep.lock_wait_ns,
        "uuar_footprint": rep.endpoint_usage["uuars"],
        "memory_footprint": rep.endpoint_usage["memory"],
        "completed": rep.n_completed,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--placement", default="round_robin")
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    rows = []
    for traffic in TRAFFICS:
        trace = TRAFFIC_SHAPES[traffic](args.requests, seed=3)
        for n_workers in WORKER_COUNTS:
            for cat in CATEGORIES:
                rep = run_one(cat, n_workers, trace,
                              placement=args.placement)
                m = metrics_of(rep)
                rows.append({"config": {
                    "category": cat.value, "workers": n_workers,
                    "traffic": traffic, "placement": args.placement,
                    "requests": args.requests}, "metrics": m})
                row(f"fabric_{traffic}_{n_workers}w_{cat.value}",
                    1e3 / max(m["tok_per_s"], 1e-9) * 1e6,
                    f"{m['tok_per_s']:.0f}tok/s"
                    f"|p50={m['p50_ms']:.2f}ms|p99={m['p99_ms']:.2f}ms"
                    f"|occ={m['occupancy']:.2f}|fair={m['fairness']:.2f}"
                    f"|uuar={m['uuar_footprint'] * 100:.1f}%")

    # acceptance row: canonical bursty trace, 8 workers
    trace = canonical_bursty_trace()
    base = run_one(Category.MPI_EVERYWHERE, 8, trace,
                   placement=args.placement)
    for cat in (Category.SHARED_DYNAMIC, Category.STATIC,
                Category.MPI_THREADS):
        rep = run_one(cat, 8, trace, placement=args.placement)
        ratio = rep.tok_per_s / base.tok_per_s
        foot = rep.endpoint_usage["uuars"]
        ok = ratio >= 0.9 and foot <= 0.5
        rows.append({"config": {
            "category": cat.value, "workers": 8,
            "traffic": "canonical_bursty", "placement": args.placement},
            "metrics": {**metrics_of(rep), "vs_dedicated": ratio,
                        "acceptance": ok}})
        row(f"fabric_acceptance_{cat.value}",
            1e3 / max(rep.tok_per_s, 1e-9) * 1e6,
            f"vs_dedicated={ratio:.3f}x|uuar={foot * 100:.1f}%"
            f"|acceptance={'PASS' if ok else 'FAIL'}")
        assert ok, (cat, ratio, foot)

    write_bench_json("fabric", rows, out=args.out)


if __name__ == "__main__":
    main()
