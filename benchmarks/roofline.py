"""Roofline benchmark: three terms per (arch x shape) from the dry-run
artifacts (single-pod mesh, per the assignment)."""

import os

from repro.launch.roofline import load_rows, markdown_table
from benchmarks.common import row


def main(dryrun_dir: str = "experiments/dryrun",
         out_md: str = "experiments/roofline.md"):
    if not os.path.isdir(dryrun_dir):
        row("roofline_missing", 0.0,
            f"run `python -m repro.launch.dryrun --all` first ({dryrun_dir})")
        return
    rows = load_rows(dryrun_dir, mesh="single")
    for r in rows:
        if r.status != "ok":
            row(f"roofline_{r.arch}_{r.shape}", 0.0, "skipped")
            continue
        dom = max(r.compute_s, r.memory_s, r.collective_s)
        row(f"roofline_{r.arch}_{r.shape}", dom * 1e6,
            f"compute={r.compute_s:.3e}s|memory={r.memory_s:.3e}s"
            f"|collective={r.collective_s:.3e}s|bottleneck={r.bottleneck}"
            f"|useful={r.useful_ratio:.2f}|frac={r.roofline_fraction:.3f}")
    os.makedirs(os.path.dirname(out_md) or ".", exist_ok=True)
    with open(out_md, "w") as f:
        f.write("# Roofline (single-pod 16x16, v5e constants)\n\n")
        f.write(markdown_table(rows) + "\n")


if __name__ == "__main__":
    main()
