"""Scalable endpoints on TPU collectives (paper Section VI, adapted).

For a real model's gradient pytree (smollm-360m, 219 tensors), each
endpoint category produces a bucket plan (channels = QPs, bucket size =
Postlist); the alpha-beta ICI model then gives the estimated gradient-sync
time on a 16-wide data axis, alongside the TPU-side resource usage
(staging buffers = the uUAR analogue).  The same ladder as Fig. 12, in the
TPU domain — the HLO-level validation (collective op counts per category)
lives in tests/test_comm_engine.py."""

import numpy as np

from repro.comm.bucketing import make_bucket_plan
from repro.comm.costs import estimate_sync_time
from repro.core.channels import plan_for
from repro.core.endpoints import Category
from repro.models.model import Model
from repro.configs import get_config
from benchmarks.common import row


def _unstack_layers(abstract_tree):
    """Split scan-stacked layer params into per-layer leaves — the logical
    communication producers are per-layer gradient tensors."""
    import jax
    import numpy as np

    leaves, treedef = jax.tree.flatten(abstract_tree)
    out = []
    for leaf in leaves:
        if leaf.ndim >= 2 and leaf.shape[0] <= 128 and np.prod(
                leaf.shape[1:]) > leaf.shape[0]:
            out.extend([jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)]
                       * leaf.shape[0])
        else:
            out.append(leaf)
    return out


def main():
    model = Model(get_config("smollm-360m"))
    grads = _unstack_layers(model.abstract_params())
    n_leaves = len(__import__("jax").tree.leaves(grads))
    total_mb = model.n_params() * 4 / 2**20

    rows = []
    for cat in Category:
        plan = plan_for(cat)
        bplan = make_bucket_plan(grads, plan)
        bytes_list = bplan.bucket_bytes()
        cost = estimate_sync_time(bytes_list, plan, axis_size=16)
        rows.append((cat, plan, bplan, cost))

    base = next(c.seconds for cat, _, _, c in rows
                if cat == Category.MPI_EVERYWHERE)
    for cat, plan, bplan, cost in rows:
        row(f"endpoint_{cat.value}", cost.seconds * 1e6,
            f"sync_ms={cost.seconds*1e3:.2f}|vs_everywhere="
            f"{base / cost.seconds * 100:.0f}%|buckets={bplan.n_buckets}"
            f"|staging_buffers={plan.staging_buffers(n_leaves)}"
            f"|alpha_ms={cost.alpha_seconds*1e3:.3f}"
            f"|beta_ms={cost.beta_seconds*1e3:.2f}")
    row("endpoint_grad_bytes", 0.0,
        f"{n_leaves}tensors|{total_mb:.0f}MB_fp32")


if __name__ == "__main__":
    main()
