"""Paper Fig. 2: the two extremes — per-thread dedicated endpoints vs one
shared endpoint: throughput and wasted hardware resources."""

from repro.core import Category, EndpointModel
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES
from benchmarks.common import row


def main():
    for cat in (Category.MPI_EVERYWHERE, Category.MPI_THREADS):
        for t in (1, 2, 4, 8, 16):
            m = EndpointModel.build(cat, t)
            r = message_rate(m, features=ALL_FEATURES, msgs_per_thread=2048)
            row(f"fig2_{cat.value}_{t}threads", 1.0 / r.rate_mmps,
                f"{r.rate_mmps:.1f}Mmsgs/s|wasted_uuars={m.usage.uuars_wasted}"
                f"|waste={m.usage.waste_fraction * 100:.1f}%")


if __name__ == "__main__":
    main()
