"""Paper Fig. 7: CTX sharing — flat with Postlist; without Postlist the
contiguous-UAR BlueFlame anomaly bites at 16-way ("2xQPs" recovers it,
"Sharing 2" shows the UAR-sharing penalty)."""

from repro.core import TDSharing, build_ctx_shared
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES
from benchmarks.common import row


def main():
    fwp = ALL_FEATURES.without("postlist")
    for ways in (1, 2, 4, 8, 16):
        variants = [
            ("all", build_ctx_shared(16, ways), ALL_FEATURES),
            ("all_wo_postlist", build_ctx_shared(16, ways), fwp),
            ("all_wo_postlist_2xqps",
             build_ctx_shared(16, ways, two_x=True), fwp),
            ("all_wo_postlist_sharing2",
             build_ctx_shared(16, ways, td_sharing=TDSharing.SHARED_UAR),
             fwp),
        ]
        for label, m, feats in variants:
            r = message_rate(m, features=feats, msgs_per_thread=2048)
            row(f"fig7_ctx{ways}way_{label}", 1.0 / r.rate_mmps,
                f"{r.rate_mmps:.1f}Mmsgs/s|uars={m.usage.uars}"
                f"|uuars={m.usage.uuars}")


if __name__ == "__main__":
    main()
