"""Serving analogue of the paper's Fig. 2 extremes comparison: the same
mixed-length request set through wave (static) scheduling and through
continuous batching at each slot-pool sharing category (DESIGN.md §3),
plus the hot-path acceptance rows for the fused decode horizon +
bucketed prefill (DESIGN.md §10).

Category rows report tokens/s with p50/p99 request latency, pool
occupancy, host syncs per token, and the matching endpoint model's
relative hardware footprint.  Horizon rows drive the CANONICAL bursty
trace (`serve.fabric.traffic.canonical_bursty_trace`) through a tiny
config where per-token host overhead dominates — the serving twin of the
paper's message-rate microbenchmarks — and record the K=1-oracle
speedup, host syncs per token, and the jit compile counters
(specializations stay bounded by the bucket set).  Engines are warmed
(compile excluded) before every timed pass.

  PYTHONPATH=src python -m benchmarks.bench_serve_continuous \
      [--arch smollm-360m] [--requests 12] [--slots 4] [--horizons 1,8]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import row, write_bench_json
from repro.configs import get_smoke_config
from repro.core.endpoints import Category
from repro.models.model import Model
from repro.serve.engine import ContinuousEngine, Request, ServeEngine, \
    _shared_steps
from repro.serve.fabric.traffic import canonical_bursty_trace
from repro.serve.slots import SlotPool

# dedicated slot / scalable middle / one shared wave (paper Section VI)
CATEGORIES = (Category.MPI_EVERYWHERE, Category.SHARED_DYNAMIC,
              Category.STATIC, Category.MPI_THREADS)
PROMPT_LENGTHS = (8, 16, 32)


def make_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(
                        1, cfg.vocab,
                        int(rng.choice(PROMPT_LENGTHS))).astype(np.int32),
                    max_new_tokens=int(rng.integers(6, 14)))
            for i in range(n)]


def _drive(build, make):
    """Warm on the IDENTICAL request set so every jit shape (each prompt
    length, every wave batch size) compiles before the timed pass."""
    warm = build()
    for r in make():
        warm.submit(r)
    warm.run()
    eng = build()
    for r in make():
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    dt = max(time.perf_counter() - t0, 1e-9)
    total = sum(len(r.output) for r in done)
    lat = sorted(eng.latency.values())
    p50 = lat[int(0.50 * (len(lat) - 1))]
    p99 = lat[int(0.99 * (len(lat) - 1))]
    return eng, total, dt, p50, p99


def _sync_stats(eng, total):
    return {"host_syncs": eng.stats["host_syncs"],
            "host_syncs_per_token": eng.stats["host_syncs"] / max(1, total),
            "decode_calls": eng.stats["decode_calls"],
            "prefill_calls": eng.stats["prefills"]}


def category_rows(args, rows):
    cfg = get_smoke_config(args.arch)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    base_config = {"arch": args.arch, "requests": args.requests,
                   "slots": args.slots, "max_len": args.max_len}

    _, total, dt, p50, p99 = _drive(
        lambda: ServeEngine(cfg, params, n_slots=args.slots,
                            max_len=args.max_len),
        lambda: make_requests(cfg, args.requests))
    wave_tps = total / dt
    row("serve_wave", 1e6 * dt / total,
        f"{wave_tps:.1f}tok/s|p50={p50 * 1e3:.0f}ms|p99={p99 * 1e3:.0f}ms")
    rows.append({"config": {**base_config, "engine": "wave"},
                 "metrics": {"tok_per_s": wave_tps, "p50_s": p50,
                             "p99_s": p99, "tokens": total}})

    for cat in CATEGORIES:
        eng, total, dt, p50, p99 = _drive(
            lambda c=cat: ContinuousEngine(cfg, params, n_slots=args.slots,
                                           max_len=args.max_len,
                                           slot_level=c.level),
            lambda: make_requests(cfg, args.requests))
        tps = total / dt
        usage = SlotPool(cat.level, args.slots).endpoint_usage()
        syncs = _sync_stats(eng, total)
        row(f"serve_continuous_{cat.value}", 1e6 * dt / total,
            f"{tps:.1f}tok/s|p50={p50 * 1e3:.0f}ms|p99={p99 * 1e3:.0f}ms"
            f"|group={eng.pool.group_size}|occ={eng.occupancy:.2f}"
            f"|vs_wave={tps / wave_tps:.2f}x"
            f"|syncs/tok={syncs['host_syncs_per_token']:.2f}"
            f"|uuar_footprint={usage['uuars'] * 100:.1f}%")
        rows.append({"config": {**base_config, "engine": "continuous",
                                "category": cat.value},
                     "metrics": {"tok_per_s": tps, "p50_s": p50,
                                 "p99_s": p99, "tokens": total,
                                 "group_size": eng.pool.group_size,
                                 "occupancy": eng.occupancy,
                                 "vs_wave": tps / wave_tps,
                                 "uuar_footprint": usage["uuars"],
                                 **syncs}})


def tiny_hotpath_config():
    """The horizon acceptance config: small enough that per-token host
    overhead (dispatch + blocking sync + python slot loop) dominates the
    forward pass — the regime the fused horizon exists for, exactly as
    the paper's Fig. 2 message-rate benchmarks use tiny messages to
    expose per-message initiation overheads."""
    return dataclasses.replace(
        get_smoke_config("smollm-360m"),
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        d_head=16)


def trace_requests(cfg, n=None):
    """The canonical bursty trace as real requests (prompt tokens keyed
    by rid exactly like ``serve.fabric.EngineWorker.prompt_fn``)."""
    out = []
    for a in canonical_bursty_trace()[:n]:
        rng = np.random.default_rng(a.rid)
        out.append(Request(
            rid=a.rid,
            prompt=rng.integers(1, cfg.vocab,
                                size=a.prompt_len).astype(np.int32),
            max_new_tokens=a.max_new_tokens))
    return out


def horizon_rows(args, rows):
    cfg = tiny_hotpath_config()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    base_config = {"arch": "tiny-hotpath", "trace": "canonical_bursty",
                   "slots": args.slots, "max_len": 64}

    def drive(k, buckets, repeat=3):
        def build():
            return ContinuousEngine(cfg, params, n_slots=args.slots,
                                    max_len=64, decode_horizon=k,
                                    prefill_buckets=buckets)
        best = None
        for _ in range(repeat):        # best-of-N: CI boxes are noisy
            eng, total, dt, p50, p99 = _drive(
                build, lambda: trace_requests(cfg, args.trace_requests))
            if best is None or total / dt > best[1] / best[2]:
                best = (eng, total, dt)
        return best

    horizons = sorted({1, *args.horizons})
    base_tps = None
    steps = _shared_steps(cfg, False)

    def compile_counts():
        # _cache_size is jax's (private) per-shape jit cache counter; on
        # a jax without it, keep the bench alive with zeroed columns
        def size(fn):
            probe = getattr(fn, "_cache_size", lambda: 0)
            return probe()
        return {"compiles_admit": size(steps.admit_packed),
                "compiles_prefill_exact": size(steps.prefill),
                "compiles_horizon": size(steps.horizon)}

    for k in horizons:
        buckets = None if k == 1 else "auto"       # K=1 = today's path
        before = compile_counts()                  # shared jit caches are
        eng, total, dt = drive(k, buckets)         # cumulative: report the
        tps = total / dt                           # per-row deltas
        if k == 1:
            base_tps = tps
        syncs = _sync_stats(eng, total)
        metrics = {"tok_per_s": tps, "tokens": total,
                   "decode_horizon": k,
                   "prefill_buckets": list(eng.prefill_buckets),
                   "occupancy": eng.occupancy,
                   "vs_k1": tps / base_tps,
                   "decode_steps": eng.stats["decode_steps"],
                   **{key: val - before[key]
                      for key, val in compile_counts().items()},
                   **syncs}
        row(f"serve_horizon_K{k}", 1e6 * dt / total,
            f"{tps:.1f}tok/s|vs_K1={tps / base_tps:.2f}x"
            f"|syncs/tok={syncs['host_syncs_per_token']:.3f}"
            f"|occ={eng.occupancy:.2f}"
            f"|compiles={metrics['compiles_admit']}admit"
            f"+{metrics['compiles_horizon']}horizon")
        rows.append({"config": {**base_config, "decode_horizon": k,
                                "buckets": "auto" if buckets else "off"},
                     "metrics": metrics})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--horizons", default="1,8",
                    help="comma list of decode horizons for the "
                         "canonical-trace acceptance rows")
    ap.add_argument("--trace-requests", type=int, default=None,
                    help="truncate the canonical bursty trace (default: "
                         "all 96 requests)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)
    args.horizons = tuple(int(tok) for tok in
                          str(args.horizons).split(",") if tok.strip())

    rows = []
    category_rows(args, rows)
    horizon_rows(args, rows)
    write_bench_json("serve", rows, out=args.out)


if __name__ == "__main__":
    main()
