"""Serving analogue of the paper's Fig. 2 extremes comparison: the same
mixed-length request set through wave (static) scheduling and through
continuous batching at each slot-pool sharing category (DESIGN.md §3).

Rows report tokens/s with p50/p99 request latency, pool occupancy, and the
matching endpoint model's relative hardware footprint, so both sides of
the dedicated-vs-shared tradeoff appear in one table.  Engines are warmed
(compile excluded) before the timed pass.

  PYTHONPATH=src python -m benchmarks.bench_serve_continuous \
      [--arch smollm-360m] [--requests 12] [--slots 4]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import row, write_bench_json
from repro.configs import get_smoke_config
from repro.core.endpoints import Category
from repro.models.model import Model
from repro.serve.engine import ContinuousEngine, Request, ServeEngine
from repro.serve.slots import SlotPool

# dedicated slot / scalable middle / one shared wave (paper Section VI)
CATEGORIES = (Category.MPI_EVERYWHERE, Category.SHARED_DYNAMIC,
              Category.STATIC, Category.MPI_THREADS)
PROMPT_LENGTHS = (8, 16, 32)


def make_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(
                        1, cfg.vocab,
                        int(rng.choice(PROMPT_LENGTHS))).astype(np.int32),
                    max_new_tokens=int(rng.integers(6, 14)))
            for i in range(n)]


def _drive(build, cfg, n_requests):
    """Warm on the IDENTICAL request set so every jit shape (each prompt
    length, every wave batch size) compiles before the timed pass."""
    warm = build()
    for r in make_requests(cfg, n_requests):
        warm.submit(r)
    warm.run()
    eng = build()
    for r in make_requests(cfg, n_requests):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    dt = max(time.perf_counter() - t0, 1e-9)
    total = sum(len(r.output) for r in done)
    lat = sorted(eng.latency.values())
    p50 = lat[int(0.50 * (len(lat) - 1))]
    p99 = lat[int(0.99 * (len(lat) - 1))]
    return eng, total, dt, p50, p99


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    cfg = get_smoke_config(args.arch)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    base_config = {"arch": args.arch, "requests": args.requests,
                   "slots": args.slots, "max_len": args.max_len}
    rows = []

    _, total, dt, p50, p99 = _drive(
        lambda: ServeEngine(cfg, params, n_slots=args.slots,
                            max_len=args.max_len),
        cfg, args.requests)
    wave_tps = total / dt
    row("serve_wave", 1e6 * dt / total,
        f"{wave_tps:.1f}tok/s|p50={p50 * 1e3:.0f}ms|p99={p99 * 1e3:.0f}ms")
    rows.append({"config": {**base_config, "engine": "wave"},
                 "metrics": {"tok_per_s": wave_tps, "p50_s": p50,
                             "p99_s": p99, "tokens": total}})

    for cat in CATEGORIES:
        eng, total, dt, p50, p99 = _drive(
            lambda c=cat: ContinuousEngine(cfg, params, n_slots=args.slots,
                                           max_len=args.max_len, category=c),
            cfg, args.requests)
        tps = total / dt
        usage = SlotPool(cat, args.slots).endpoint_usage()
        row(f"serve_continuous_{cat.value}", 1e6 * dt / total,
            f"{tps:.1f}tok/s|p50={p50 * 1e3:.0f}ms|p99={p99 * 1e3:.0f}ms"
            f"|group={eng.pool.group_size}|occ={eng.occupancy:.2f}"
            f"|vs_wave={tps / wave_tps:.2f}x"
            f"|uuar_footprint={usage['uuars'] * 100:.1f}%")
        rows.append({"config": {**base_config, "engine": "continuous",
                                "category": cat.value},
                     "metrics": {"tok_per_s": tps, "p50_s": p50,
                                 "p99_s": p99, "tokens": total,
                                 "group_size": eng.pool.group_size,
                                 "occupancy": eng.occupancy,
                                 "vs_wave": tps / wave_tps,
                                 "uuar_footprint": usage["uuars"]}})

    write_bench_json("serve", rows, out=args.out)


if __name__ == "__main__":
    main()
