"""Paper Fig. 5: BUF sharing — hurts only when the NIC DMA-reads the
payload (no Inlining), via TLB-rail serialization on the shared line."""

from repro.core import build_ctx_shared
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES, BufferConfig
from benchmarks.common import row


def main():
    m = build_ctx_shared(16, 1)
    for ways in (1, 2, 4, 8, 16):
        bufs = BufferConfig.shared(16, ways)
        for label, feats in [("all", ALL_FEATURES),
                             ("all_wo_inline", ALL_FEATURES.without("inline"))]:
            r = message_rate(m, features=feats, buffers=bufs,
                             msgs_per_thread=2048)
            row(f"fig5_buf{ways}way_{label}", 1.0 / r.rate_mmps,
                f"{r.rate_mmps:.1f}Mmsgs/s")


if __name__ == "__main__":
    main()
