"""Paper Fig. 11: QP sharing — lock + atomic depth contention serializes
posts; the NIC parallelism goes unused."""

from repro.core import build_qp_shared
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES
from benchmarks.common import row


def main():
    for ways in (1, 2, 4, 8, 16):
        m = build_qp_shared(16, ways)
        for label, feats in [
                ("all", ALL_FEATURES),
                ("all_wo_postlist", ALL_FEATURES.without("postlist")),
                ("all_wo_unsignaled", ALL_FEATURES.without("unsignaled"))]:
            r = message_rate(m, features=feats, msgs_per_thread=2048)
            row(f"fig11_qp{ways}way_{label}", 1.0 / r.rate_mmps,
                f"{r.rate_mmps:.1f}Mmsgs/s|qps={m.usage.qps}")


if __name__ == "__main__":
    main()
