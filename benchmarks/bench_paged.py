"""Paged KV-cache bench (DESIGN.md §13): shared page pool vs the
dedicated reservation, in pure virtual time.

The paper's Table I point restated for serving: the KV cache is the
endpoint's registered memory — by far the largest per-session
reservation, mostly idle.  Today every admitted session pins
``max_len`` rows (``max_pages`` pages) for its whole residency even
though the canonical bursty trace needs ~4 of 16 on average.  The paged
layout (pages level 4, one pool per worker, ``page_budget`` = 0.4× the
dedicated reservation) reserves only what each session's span can
reach, admission deferring — never corrupting — when the pool is dry.

Acceptance (asserted, emitted as the ``paged_acceptance`` row of
BENCH_paged.json, gated by ``check_regression``):

* pooled throughput ≥ 0.95× the dedicated-budget paged run's (same
  layout, only the budgets differ — the pool must not cost tokens);
* reserved cache footprint ≤ 0.4× dedicated (that is the budget, and
  the run must COMPLETE inside it);
* ≥ 2× the live sessions per reserved page before the first stall:
  FIFO-replaying the trace's page needs into the pooled budget admits
  at least twice the sessions the dedicated layout fits in the same
  memory (which pins ``max_pages`` per session regardless of need).

Pure virtual time (``SimWorker`` fleets + a host-only ``PagePool``
replay): host-milliseconds, deterministic, CI-comparable bit-for-bit.

  PYTHONPATH=src:. python -m benchmarks.bench_paged
"""

from __future__ import annotations

import argparse

from benchmarks.common import row, write_bench_json
from repro.core.plan import SharingVector
from repro.serve.fabric import build_sim_fleet, canonical_bursty_trace
from repro.serve.pages import PagePool

N_WORKERS = 4
N_SLOTS = 8
MAX_LEN = 128
PAGE_SIZE = 8
MAX_PAGES = MAX_LEN // PAGE_SIZE
DEDICATED_PAGES = N_SLOTS * MAX_PAGES          # per worker
POOL_FRAC = 0.4
POOL_BUDGET = int(POOL_FRAC * DEDICATED_PAGES)  # 51 of 128

#: Both rows run the SAME paged layout; only the pages level (and so
#: the budget keying) differs — the comparison isolates pooling.
VECTORS = {
    1: SharingVector(slots=1, channels=3, execs=4, pages=1),
    4: SharingVector(slots=1, channels=3, execs=4, pages=4),
}


def page_need(arrival) -> int:
    span = min(arrival.prompt_len + arrival.max_new_tokens, MAX_LEN)
    return max(1, -(-span // PAGE_SIZE))


def run_fleet(pages_level: int, budget):
    rep = build_sim_fleet(N_WORKERS, VECTORS[pages_level],
                          n_slots=N_SLOTS, page_size=PAGE_SIZE,
                          max_len=MAX_LEN, page_budget=budget) \
        .run(canonical_bursty_trace())
    assert rep.n_completed == rep.n_arrivals, (pages_level,
                                               rep.n_completed)
    return rep


def sessions_before_stall(budget: int) -> int:
    """FIFO-replay the trace's page needs into one pooled budget: how
    many sessions are live when the pool first refuses one (the
    admission-capacity measure; deterministic host bookkeeping)."""
    trace = canonical_bursty_trace()
    pool = PagePool(4, len(trace), MAX_PAGES, total_pages=budget)
    for i, a in enumerate(trace):
        if pool.alloc(i, page_need(a)) is None:
            return i
    return len(trace)


def metrics_of(rep) -> dict:
    return {
        "tok_per_s": rep.tok_per_s,
        "p50_ms": rep.latency_percentile(0.5) / 1e6,
        "p99_ms": rep.latency_percentile(0.99) / 1e6,
        "occupancy": rep.occupancy,
        "completed": rep.n_completed,
        "page_hwm_frac": rep.page_hwm_frac,
        "page_deferrals": rep.page_deferrals,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    rows, reps = [], {}
    for pages_level, budget in ((1, None), (4, POOL_BUDGET)):
        rep = run_fleet(pages_level, budget)
        reps[pages_level] = rep
        m = metrics_of(rep)
        reserved = budget if budget is not None else DEDICATED_PAGES
        m["reserved_pages_per_worker"] = reserved
        m["footprint"] = reserved / DEDICATED_PAGES
        rows.append({"config": {
            "mode": "paged", "pages_level": pages_level,
            "page_size": PAGE_SIZE, "page_budget": reserved,
            "workers": N_WORKERS, "n_slots": N_SLOTS,
            "max_len": MAX_LEN, "trace": "canonical_bursty"},
            "metrics": m})
        row(f"paged_p{pages_level}_budget{reserved}",
            1e3 / max(m["tok_per_s"], 1e-9) * 1e6,
            f"{m['tok_per_s']:.0f}tok/s"
            f"|reserved={m['footprint'] * 100:.0f}%"
            f"|hwm={m['page_hwm_frac'] * 100:.0f}%"
            f"|{m['page_deferrals']}deferrals")

    # ----- acceptance ----------------------------------------------------
    dedicated, pooled = reps[1], reps[4]
    ratio = pooled.tok_per_s / dedicated.tok_per_s
    foot = POOL_BUDGET / DEDICATED_PAGES
    live_pooled = sessions_before_stall(POOL_BUDGET)
    live_dedicated = max(1, POOL_BUDGET // MAX_PAGES)
    live_ratio = live_pooled / live_dedicated
    ok = ratio >= 0.95 and foot <= POOL_FRAC and live_ratio >= 2.0
    rows.append({"config": {
        "mode": "acceptance", "pool_frac": POOL_FRAC,
        "page_size": PAGE_SIZE, "workers": N_WORKERS,
        "n_slots": N_SLOTS, "max_len": MAX_LEN,
        "trace": "canonical_bursty"},
        "metrics": {
            "tok_per_s_vs_dedicated": ratio,
            "pooled_tok_per_s": pooled.tok_per_s,
            "dedicated_tok_per_s": dedicated.tok_per_s,
            "footprint": foot,
            "sessions_before_stall": live_pooled,
            "dedicated_sessions_same_memory": live_dedicated,
            "sessions_ratio": live_ratio,
            "pooled_deferrals": pooled.page_deferrals,
            "acceptance": ok}})
    row("paged_acceptance",
        1e3 / max(pooled.tok_per_s, 1e-9) * 1e6,
        f"vs_dedicated={ratio:.3f}x|reserved={foot * 100:.0f}%"
        f"|sessions={live_pooled}v{live_dedicated}({live_ratio:.1f}x)"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (ratio, foot, live_ratio)

    write_bench_json("paged", rows, out=args.out)


if __name__ == "__main__":
    main()
