"""Paper Fig. 12: global-array DGEMM kernel with scalable endpoints.

16 client threads fetch A/B tiles from a server node, multiply, and write C
tiles back (NWChem-style get-compute-put).  The tile DGEMMs run for real in
JAX; the tile transfers are RDMA messages whose rate comes from the
calibrated ibsim under the paper's conservative semantics (no Postlist /
Unsignaled, BlueFlame) — reproducing the 108/94/65/64/3 %-of-everywhere
ladder with the exact per-category resource usage."""

import jax
import jax.numpy as jnp

from repro.core import Category, EndpointModel, paper_categories
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import CONSERVATIVE
from benchmarks.common import row, timed

TILE = 128
TILES = 4            # global matrix = (TILES*TILE)^2


def _dgemm_pass():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (TILES * TILE, TILES * TILE), jnp.float32)
    b = jax.random.normal(key, (TILES * TILE, TILES * TILE), jnp.float32)

    @jax.jit
    def tile_dgemm(at, bt):
        return at @ bt

    c = jnp.zeros_like(a)
    for i in range(TILES):
        for j in range(TILES):
            acc = jnp.zeros((TILE, TILE), jnp.float32)
            for k in range(TILES):
                at = jax.lax.dynamic_slice(a, (i * TILE, k * TILE),
                                           (TILE, TILE))
                bt = jax.lax.dynamic_slice(b, (k * TILE, j * TILE),
                                           (TILE, TILE))
                acc = acc + tile_dgemm(at, bt)
            c = jax.lax.dynamic_update_slice(c, acc, (i * TILE, j * TILE))
    return float(jnp.sum(c))


def main():
    # the real compute side (validates the application structure)
    _, dt = timed(_dgemm_pass, repeat=1)
    row("fig12_dgemm_compute", dt * 1e6, f"{TILES}x{TILES}tiles_of_{TILE}")

    base = None
    for cat in paper_categories():
        m = EndpointModel.build(cat, 16)
        r = message_rate(m, features=CONSERVATIVE, msgs_per_thread=2048)
        if cat == Category.MPI_EVERYWHERE:
            base = r.rate_mmps
    for cat in paper_categories():
        m = EndpointModel.build(cat, 16)
        r = message_rate(m, features=CONSERVATIVE, msgs_per_thread=2048)
        u = m.usage
        rel = r.rate_mmps / base * 100
        row(f"fig12_{cat.value}", 1.0 / r.rate_mmps,
            f"{rel:.0f}%of_everywhere|uuars={u.uuars}({u.uuars / 256 * 100:.2f}%)"
            f"|qps={u.qps}|mem_mb={u.memory_bytes_active / 2**20:.2f}")


if __name__ == "__main__":
    main()
