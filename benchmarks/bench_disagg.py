"""Prefill/decode disaggregation: throughput floor, decode-tail
improvement, and live-migration token conservation (DESIGN.md §17).

All rows run the virtual-time sim fleet — deterministic pure
arithmetic, so every metric gates tightly in ``check_regression.py``.

Rows:

* ``disagg_prefill_heavy`` — THE acceptance row: on a prefill-heavy
  trace (3/4 of arrivals carry 256-token prompts, tiny decode budgets,
  steady poisson load) the ``2P+2D`` split keeps >= 0.9x the co-located
  4-worker fleet's throughput while IMPROVING the decode p99 (the
  latency tail of the short-prompt decode-dominant foreground — on the
  co-located fleet those requests stall behind long prefill admits on
  the same worker; a decode-only worker never pays one).
* ``disagg_session`` — the canonical session trace under ``2P+2D``:
  request conservation vs co-located, one handoff per completion,
  size-proportional KV movement.
* ``disagg_migration`` — a decode→decode live migration mid-run: the
  moved sessions finish elsewhere with identical per-request token
  counts (zero lost, zero duplicated).

  PYTHONPATH=src:. python -m benchmarks.bench_disagg
"""

from __future__ import annotations

import argparse

from benchmarks.common import row, write_bench_json
from repro.core.endpoints import Category
from repro.serve.fabric import (build_sim_fleet, bursty_trace,
                                poisson_trace, session_trace)

N_WORKERS = 4
ROLES = "2P+2D"
#: the prefill-heavy acceptance trace: mostly long prompts, all decode
#: budgets tiny — the regime the role split is FOR
PREFILL_HEAVY = dict(mean_gap_ns=20_000.0,
                     prompt_lens=(16, 256, 256, 256),
                     new_tokens=(2, 4), seed=0)
#: foreground = the short-prompt requests whose decode tail we track
FOREGROUND_PROMPT = 16


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def _run(trace, roles=None, migrations=None, **kw):
    return build_sim_fleet(N_WORKERS, Category.SHARED_DYNAMIC,
                           roles=roles, migrations=migrations,
                           max_len=512, **kw).run(trace)


def _tokens(rep):
    return {c.rid: c.new_tokens for c in rep.completions}


def _decode_p99_ms(rep, trace):
    arr = {a.rid: a for a in trace}
    fg = [rep.latency_ns[c.rid] for c in rep.completions
          if arr[c.rid].prompt_len <= FOREGROUND_PROMPT]
    return _pct(fg, 0.99) / 1e6


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    rows = []

    # --- prefill-heavy acceptance: tput floor + decode tail -------------
    trace = poisson_trace(60, **PREFILL_HEAVY)
    base = _run(trace)
    dis = _run(trace, roles=ROLES)
    vs = dis.tok_per_s / base.tok_per_s
    d_p99, b_p99 = _decode_p99_ms(dis, trace), _decode_p99_ms(base, trace)
    conserved = _tokens(dis) == _tokens(base)
    ok = vs >= 0.9 and d_p99 < b_p99 and conserved
    rows.append({"config": {"scenario": "prefill_heavy", "roles": ROLES,
                            "workers": N_WORKERS},
                 "metrics": {
                     "tok_per_s": dis.tok_per_s,
                     "vs_colocated": vs,
                     "decode_p99_ms": d_p99,
                     "colocated_decode_p99_ms": b_p99,
                     "tokens": dis.total_new_tokens,
                     "completed": dis.n_completed,
                     "handoffs": dis.handoffs,
                     "kv_tokens_moved": dis.kv_tokens_moved,
                     "kv_bytes_moved": dis.kv_bytes_moved,
                     "acceptance": ok}})
    row("disagg_prefill_heavy", 1e3 / max(dis.tok_per_s, 1e-9) * 1e6,
        f"vs_colocated={vs:.3f}x|decode_p99={d_p99:.2f}ms"
        f"<{b_p99:.2f}ms|handoffs={dis.handoffs}"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (vs, d_p99, b_p99, conserved)

    # --- canonical session trace: conservation + handoff accounting -----
    strace = session_trace(16, 4, seed=0)
    sbase = _run(strace)
    sdis = _run(strace, roles=ROLES)
    s_ok = _tokens(sdis) == _tokens(sbase) \
        and sdis.handoffs == sdis.n_completed \
        and sdis.kv_tokens_moved > 0
    rows.append({"config": {"scenario": "session", "roles": ROLES,
                            "workers": N_WORKERS},
                 "metrics": {
                     "tok_per_s": sdis.tok_per_s,
                     "tokens": sdis.total_new_tokens,
                     "completed": sdis.n_completed,
                     "handoffs": sdis.handoffs,
                     "kv_tokens_moved": sdis.kv_tokens_moved,
                     "kv_bytes_moved": sdis.kv_bytes_moved,
                     "acceptance": s_ok}})
    row("disagg_session", 1e3 / max(sdis.tok_per_s, 1e-9) * 1e6,
        f"handoffs={sdis.handoffs}|kv_tokens={sdis.kv_tokens_moved}"
        f"|kv_bytes={sdis.kv_bytes_moved}"
        f"|acceptance={'PASS' if s_ok else 'FAIL'}")
    assert s_ok

    # --- live migration: zero token loss --------------------------------
    mtrace = bursty_trace(24, burst_size=4, new_tokens=(6, 12), seed=2)
    mbase = _run(mtrace)
    mig = _run(mtrace, migrations=[(150_000.0, 0, 2)])
    m_ok = _tokens(mig) == _tokens(mbase) and mig.migrations == 1 \
        and mig.handoffs > 0
    rows.append({"config": {"scenario": "migration",
                            "migrations": [[150_000.0, 0, 2]],
                            "workers": N_WORKERS},
                 "metrics": {
                     "tok_per_s": mig.tok_per_s,
                     "tokens": mig.total_new_tokens,
                     "completed": mig.n_completed,
                     "migrations": mig.migrations,
                     "handoffs": mig.handoffs,
                     "kv_tokens_moved": mig.kv_tokens_moved,
                     "kv_bytes_moved": mig.kv_bytes_moved,
                     "acceptance": m_ok}})
    row("disagg_migration", 1e3 / max(mig.tok_per_s, 1e-9) * 1e6,
        f"migrations={mig.migrations}|handoffs={mig.handoffs}"
        f"|conserved={_tokens(mig) == _tokens(mbase)}"
        f"|acceptance={'PASS' if m_ok else 'FAIL'}")
    assert m_ok

    write_bench_json("disagg", rows, out=args.out)


if __name__ == "__main__":
    main()
