# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (see benchmarks/common.py for the column convention).
import importlib

BENCHES = [
    "bench_table1_memory",
    "bench_fig2_extremes",
    "bench_fig3_naive_scaling",
    "bench_fig5_buf_sharing",
    "bench_fig6_cache_align",
    "bench_fig7_ctx_sharing",
    "bench_fig8_pd_mr_sharing",
    "bench_fig9_cq_sharing",
    "bench_fig11_qp_sharing",
    "bench_fig12_global_array",
    "bench_fig14_stencil",
    "bench_endpoint_collectives",
    "bench_serve_continuous",
    "bench_fabric",
    "bench_plan_space",
    "bench_adaptive",
    "bench_paged",
    "bench_obs",
    "bench_faults",
    "bench_disagg",
    "bench_tune",
    "roofline",
    "hillclimb",
]


def main() -> None:
    print("name,us_per_call,derived")
    for name in BENCHES:
        mod = importlib.import_module(f"benchmarks.{name}")
        mod.main()


if __name__ == "__main__":
    main()
