"""Plan-space auto-tuner bench (DESIGN.md §16): deterministic search
over the sharing cube on the canonical bursty trace.

Three driver rows (grid / random / anneal, fixed seed) measure what
each search buys for its eval budget: the frontier's best throughput,
best tail latency, and smallest footprint, plus how many unique
simulations were paid for and how many plans survived dominance.

The acceptance row restates the paper's headline through the tuner: a
<= 64-eval search must emit a Pareto front containing a plan with
>= 0.99x the best hand-written diagonal's throughput at <= 0.5x its
footprint — the tuner has to FIND the scalable middle, not be handed
it.  The reproducibility row re-runs the annealing search with the same
seed and requires the identical frontier and a byte-identical SQLite
plan repository.

  PYTHONPATH=src:. python -m benchmarks.bench_tune
"""

from __future__ import annotations

import argparse
import hashlib
import os
import tempfile

from benchmarks.common import row, write_bench_json
from repro.tune import PlanRepository, SPACES, Tuner

SPACE_NAME = "sharing"
TRACE = "canonical_bursty"
BUDGET = 64
SEED = 0


def _cfg(driver: str, **extra) -> dict:
    return {"space": SPACE_NAME, "driver": driver, "trace": TRACE,
            "budget_evals": BUDGET, "seed": SEED, **extra}


def _sha256(path: str) -> str:
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def run_driver(driver: str):
    return Tuner(SPACES[SPACE_NAME], trace=TRACE, driver=driver,
                 budget_evals=BUDGET, seed=SEED).run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    rows, results = [], {}
    for driver in ("grid", "random", "anneal"):
        res = run_driver(driver)
        results[driver] = res
        best_tok = res.best_by("tok_per_s")
        best_p99 = res.best_by("p99_ms")
        best_foot = res.best_by("footprint")
        m = {
            "tok_per_s": best_tok.tok_per_s,
            "p99_ms": best_p99.p99_ms,
            "footprint": best_foot.footprint,
            "evals": res.n_evals,
            "frontier_size": len(res.front),
        }
        rows.append({"config": _cfg(driver), "metrics": m})
        row(f"tune_{driver}",
            1e3 / max(m["tok_per_s"], 1e-9) * 1e6,
            f"front={m['frontier_size']}|evals={m['evals']}"
            f"|best={best_tok.plan.vector.label}"
            f"@{m['tok_per_s']:.0f}tok/s"
            f"|min_foot={m['footprint'] * 100:.1f}%")

    # ----- acceptance: the tuner finds the scalable middle ---------------
    grid = results["grid"]
    diagonals = {}
    for point, meas in grid.evals:
        vec = point.vector
        if vec.is_diagonal and meas.feasible:
            diagonals[vec] = meas
    best_diag = max(diagonals.values(), key=lambda m: m.tok_per_s)
    winners = [p for p in grid.front
               if p.tok_per_s >= 0.99 * best_diag.tok_per_s
               and p.footprint <= 0.5 * best_diag.footprint]
    ok = bool(winners)
    pick = winners[0] if winners else grid.front[0]
    ratio = pick.tok_per_s / best_diag.tok_per_s
    foot = pick.footprint / best_diag.footprint
    rows.append({"config": _cfg("grid", baseline="best_diagonal"),
                 "metrics": {
                     "tok_per_s": pick.tok_per_s,
                     "footprint": pick.footprint,
                     "vs_best_diagonal": ratio,
                     "footprint_vs_best_diagonal": foot,
                     "frontier_size": len(grid.front),
                     "acceptance": ok}})
    row("tune_acceptance",
        1e3 / max(pick.tok_per_s, 1e-9) * 1e6,
        f"{pick.plan.vector.label}|vs_best_diag={ratio:.3f}x"
        f"|footprint={foot * 100:.1f}%"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (ratio, foot)

    # ----- reproducibility: same seed => same frontier, same bytes -------
    rerun = run_driver("anneal")
    base = results["anneal"]
    same_front = ([(p.plan, p.objectives) for p in base.front]
                  == [(p.plan, p.objectives) for p in rerun.front])
    with tempfile.TemporaryDirectory() as tmp:
        paths = [os.path.join(tmp, f"repo_{i}.sqlite") for i in (0, 1)]
        for path, res in zip(paths, (base, rerun)):
            with PlanRepository(path, fresh=True) as repo:
                repo.store_front(res.front, traffic=res.trace)
        same_bytes = _sha256(paths[0]) == _sha256(paths[1])
    rows.append({"config": _cfg("anneal", check="reproducibility"),
                 "metrics": {"reproducible": same_front,
                             "sqlite_identical": same_bytes,
                             "frontier_size": len(base.front)}})
    row("tune_reproducible", 0.0,
        f"frontier={'same' if same_front else 'DIFFERS'}"
        f"|sqlite={'identical' if same_bytes else 'DIFFERS'}")
    assert same_front and same_bytes

    write_bench_json("tune", rows, out=args.out)


if __name__ == "__main__":
    main()
