"""Paper Figs. 9-10: CQ sharing — lock/atomic contention on the completion
path; worst without Unsignaled Completions (every WQE polls), and the
Postlist-vs-Unsignaled tradeoff across q values."""

import dataclasses

from repro.core import build_cq_shared
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES
from benchmarks.common import row


def main():
    for ways in (1, 2, 4, 8, 16):
        m = build_cq_shared(16, ways)
        for label, feats in [
                ("all", ALL_FEATURES),
                ("all_wo_unsignaled", ALL_FEATURES.without("unsignaled"))]:
            r = message_rate(m, features=feats, msgs_per_thread=2048)
            row(f"fig9_cq{ways}way_{label}", 1.0 / r.rate_mmps,
                f"{r.rate_mmps:.1f}Mmsgs/s|cqs={m.usage.cqs}")
        # Fig 10: unsignaled sweep at postlist 32 and 1
        for p in (32, 1):
            for q in (1, 16, 64):
                feats = dataclasses.replace(ALL_FEATURES, postlist=p,
                                            unsignaled=q)
                r = message_rate(m, features=feats, msgs_per_thread=2048)
                row(f"fig10_cq{ways}way_p{p}_q{q}", 1.0 / r.rate_mmps,
                    f"{r.rate_mmps:.1f}Mmsgs/s")


if __name__ == "__main__":
    main()
