"""Adaptive re-planning bench (DESIGN.md §12): live SharingVector
migration on the canonical phased trace, against every frozen plan.

The paper's ``shared_dynamic``/``dynamic`` categories are runtime ideas —
resources are allocated and reclaimed as contention shifts — and this
bench restates that for serving: on a phase-shifting workload
(poisson → burst → idle → burst) no FROZEN ``SharingVector`` wins
everywhere.  The dedicated diagonal holds peak throughput but burns full
footprint through a 4 ms idle window; the shared diagonals halve the
footprint but pay 2-3× on the 48-request burst instants.  The adaptive
fleet — a ``core.adapt.Replanner`` sampling fabric telemetry every
window, promoting under contention, demoting lazily when idle — tracks
the per-phase-best static plan within 5% while its time-weighted mean
footprint sits near the shared diagonals'.

Acceptance (asserted, emitted as the ``adaptive_acceptance`` row of
BENCH_adapt.json):

* adaptive aggregate throughput ≥ 0.95× the per-phase-BEST static
  plan's (per phase, the best static duration; summed over busy phases);
* adaptive mean footprint ≤ the frozen dedicated diagonal's;
* every frozen DIAGONAL loses ≥ 5% throughput on some phase or carries
  a higher mean footprint than the adaptive fleet — no plan the old
  scalar ``Category`` could freeze dominates.  (The off-diagonal
  ``s1c3e4`` point rides along for reference: it was hand-picked by
  PR 4's plan-space sweep on this very traffic shape, i.e. it already
  encodes trace knowledge — the adaptive fleet's claim is matching that
  oracle-informed pick without being told.)

Pure virtual time (``SimWorker`` fleets): host-milliseconds, fully
deterministic, CI-comparable bit-for-bit.

  PYTHONPATH=src:. python -m benchmarks.bench_adaptive
"""

from __future__ import annotations

import argparse

from benchmarks.common import row, write_bench_json
from repro.core.adapt import Replanner
from repro.core.plan import Hints, SharingVector, resolve
from repro.serve.fabric import build_sim_fleet, canonical_phased_trace

N_WORKERS = 8
N_SLOTS = 4
ADAPT_WINDOW_NS = 100_000.0

#: Frozen competitors: the four diagonals plus PR-4's off-diagonal
#: acceptance point.
STATICS = [SharingVector.diagonal(level) for level in (1, 2, 3, 4)] \
    + [SharingVector(slots=1, channels=3, execs=4)]


def _label(v: SharingVector) -> str:
    return v.label


def phase_durations(rep, trace, phases) -> dict:
    """Per busy phase: last completion of the phase's arrivals minus the
    phase start — the time the fleet took to clear that phase's load."""
    done = {c.rid: c.t_done_ns for c in rep.completions}
    return {p.name: max(done[a.rid] for a in p.arrivals(trace))
            - p.t_start_ns
            for p in phases if p.name != "idle"}


def run_static(vector, trace):
    rep = build_sim_fleet(N_WORKERS, vector, n_slots=N_SLOTS).run(trace)
    assert rep.n_completed == rep.n_arrivals, (vector, rep.n_completed)
    return rep


def run_adaptive(start, trace):
    adapt = Replanner(start, n_workers=N_WORKERS, n_slots=N_SLOTS)
    rep = build_sim_fleet(N_WORKERS, start, n_slots=N_SLOTS, adapt=adapt,
                          adapt_window_ns=ADAPT_WINDOW_NS).run(trace)
    assert rep.n_completed == rep.n_arrivals
    return rep


def metrics_of(rep, durations) -> dict:
    return {
        "tok_per_s": rep.tok_per_s,
        "p50_ms": rep.latency_percentile(0.5) / 1e6,
        "p99_ms": rep.latency_percentile(0.99) / 1e6,
        "occupancy": rep.occupancy,
        "mean_footprint": rep.mean_footprint,
        "phase_ms": {k: v / 1e6 for k, v in durations.items()},
        "transitions": len(rep.transitions),
        "completed": rep.n_completed,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args([] if __name__ != "__main__" else None)

    trace, phases = canonical_phased_trace()
    rows, static_dur, static_rep = [], {}, {}
    for vector in STATICS:
        rep = run_static(vector, trace)
        dur = phase_durations(rep, trace, phases)
        static_dur[vector], static_rep[vector] = dur, rep
        m = metrics_of(rep, dur)
        rows.append({"config": {
            "mode": "static", "slots_level": vector.slots,
            "channels_level": vector.channels,
            "execs_level": vector.execs, "workers": N_WORKERS,
            "n_slots": N_SLOTS, "trace": "canonical_phased"},
            "metrics": m})
        row(f"adapt_static_{_label(vector)}",
            1e3 / max(m["tok_per_s"], 1e-9) * 1e6,
            f"{m['tok_per_s']:.0f}tok/s"
            f"|foot={m['mean_footprint'] * 100:.1f}%|"
            + "|".join(f"{k}={v:.2f}ms" for k, v in m["phase_ms"].items()))

    # the adaptive fleet starts where the latency-indifferent planner
    # lands (resolve(Hints()) — the paper's scalable-middle default)
    start = resolve(Hints(), n_workers=N_WORKERS, n_slots=N_SLOTS)
    rep = run_adaptive(start, trace)
    dur = phase_durations(rep, trace, phases)
    m = metrics_of(rep, dur)
    final = rep.vector
    rows.append({"config": {
        "mode": "adaptive", "start": _label(start),
        "adapt_window_ns": ADAPT_WINDOW_NS, "workers": N_WORKERS,
        "n_slots": N_SLOTS, "trace": "canonical_phased"},
        "metrics": {**m, "final_vector": _label(final),
                    "n_windows": rep.n_windows}})
    row(f"adapt_adaptive_from_{_label(start)}",
        1e3 / max(m["tok_per_s"], 1e-9) * 1e6,
        f"{m['tok_per_s']:.0f}tok/s|foot={m['mean_footprint'] * 100:.1f}%"
        f"|{m['transitions']}migrations|"
        + "|".join(f"{k}={v:.2f}ms" for k, v in m["phase_ms"].items()))

    # ----- acceptance ----------------------------------------------------
    total_tokens = rep.total_new_tokens
    best = {p.name: min(d[p.name] for d in static_dur.values())
            for p in phases if p.name != "idle"}
    best_static_tok_per_s = total_tokens / sum(best.values()) * 1e9
    adaptive_tok_per_s = total_tokens / sum(dur.values()) * 1e9
    ratio = adaptive_tok_per_s / best_static_tok_per_s
    dedicated = SharingVector.diagonal(1)
    foot_ok = rep.mean_footprint <= static_rep[dedicated].mean_footprint
    # no frozen DIAGONAL dominates: each loses >= 5% on some phase or
    # carries a higher mean footprint than the adaptive fleet
    beaten = []
    for vector in STATICS:
        loses_phase = any(
            static_dur[vector][ph] > 1.05 * best[ph] for ph in best)
        wastes = static_rep[vector].mean_footprint > rep.mean_footprint
        beaten.append((vector, loses_phase or wastes))
    diagonals_beaten = all(b for v, b in beaten if v.is_diagonal)
    ok = ratio >= 0.95 and foot_ok and diagonals_beaten
    rows.append({"config": {
        "mode": "acceptance", "workers": N_WORKERS, "n_slots": N_SLOTS,
        "trace": "canonical_phased", "baseline": "per_phase_best_static"},
        "metrics": {
            "vs_per_phase_best": ratio,
            "adaptive_tok_per_s": adaptive_tok_per_s,
            "best_static_tok_per_s": best_static_tok_per_s,
            "mean_footprint": rep.mean_footprint,
            "dedicated_mean_footprint":
                static_rep[dedicated].mean_footprint,
            "no_diagonal_dominates": diagonals_beaten,
            "off_diagonal_dominated": all(
                b for v, b in beaten if not v.is_diagonal),
            "acceptance": ok}})
    row("adaptive_acceptance",
        1e3 / max(adaptive_tok_per_s, 1e-9) * 1e6,
        f"vs_phase_best={ratio:.3f}x"
        f"|foot={rep.mean_footprint * 100:.1f}%"
        f"(dedicated={static_rep[dedicated].mean_footprint * 100:.0f}%)"
        f"|acceptance={'PASS' if ok else 'FAIL'}")
    assert ok, (ratio, rep.mean_footprint, beaten)

    write_bench_json("adapt", rows, out=args.out)


if __name__ == "__main__":
    main()
