"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the simulated/measured microseconds per operation
(1 / message-rate for the ibsim benchmarks) and ``derived`` is the
figure-specific quantity (rate in Mmsgs/s, % of baseline, resource counts,
roofline seconds, ...).
"""

from __future__ import annotations

import time


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.4f},{derived}")


def timed(fn, *args, repeat: int = 3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / repeat
