"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the simulated/measured microseconds per operation
(1 / message-rate for the ibsim benchmarks) and ``derived`` is the
figure-specific quantity (rate in Mmsgs/s, % of baseline, resource counts,
roofline seconds, ...).
"""

from __future__ import annotations

import json
import os
import time


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.4f},{derived}")


def write_bench_json(name: str, rows: list, out: str = None):
    """Write machine-readable results next to the CSV rows.

    ``rows`` is a list of ``{"config": {...}, "metrics": {...}}`` dicts;
    the file lands at ``$BENCH_OUT_DIR/BENCH_<name>.json`` (default CWD)
    so CI can upload every ``BENCH_*.json`` as an artifact and the perf
    trajectory accumulates across runs."""
    path = out or os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                               f"BENCH_{name}.json")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"bench": name, "schema": "config->metrics",
                   "rows": rows}, f, indent=1)
    print(f"# wrote {path}")
    return path


def timed(fn, *args, repeat: int = 3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / repeat
