"""Paper Fig. 3: naive TD-per-CTX endpoints — throughput across feature
ablations (left) and resource usage growth (right)."""

from repro.core import build_ctx_shared, naive_td_per_ctx_usage
from repro.core.ibsim.benchmark import message_rate
from repro.core.ibsim.costmodel import ALL_FEATURES
from benchmarks.common import row

FEATURES = ["all", "postlist", "unsignaled", "inline", "blueflame"]


def main():
    for t in (1, 2, 4, 8, 16):
        m = build_ctx_shared(t, 1)        # one CTX per thread, TD inside
        for f in FEATURES:
            feats = ALL_FEATURES if f == "all" else ALL_FEATURES.without(f)
            r = message_rate(m, features=feats, msgs_per_thread=2048)
            row(f"fig3_{t}threads_all_wo_{f}" if f != "all"
                else f"fig3_{t}threads_all",
                1.0 / r.rate_mmps, f"{r.rate_mmps:.1f}Mmsgs/s")
        u = naive_td_per_ctx_usage(t)
        row(f"fig3_{t}threads_resources", 0.0,
            f"qps={u.qps}|cqs={u.cqs}|uars={u.uars}|uuars={u.uuars}"
            f"|sw_mem_kb={u.sw_memory_bytes // 1024}")


if __name__ == "__main__":
    main()
