import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: for each of the three chosen cells, lower the
paper-faithful baseline and each named optimization variant, record the
roofline terms, and append the hypothesis -> change -> before/after log to
the --out file (default experiments/hillclimb.json).

  PYTHONPATH=src python -m benchmarks.hillclimb [--out PATH]

This is the single-objective ancestor of the ``repro.tune`` search
drivers (DESIGN.md §16): hand-written hypothesis -> variant -> measure
loops, where the tuner walks the same move structure automatically.
"""

import argparse
import json

from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_record

# (cell, variant-name, hypothesis, lower_cell kwargs)
PLANS = [
    ("smollm-360m", "train_4k", [
        ("baseline_tp", "16-way TP replicates attention work for 15 heads",
         {}),
        ("dp_only",
         "a 360M model should map the whole 16x16 mesh as 256-way DP: "
         "predicted ~16x compute-term drop (no replicated attention), "
         "collective term = one 1.4GB fp32 grad all-reduce",
         {"rules_preset": "dp_only", "accum_override": 1}),
        ("dp_only_bf16cast",
         "cast params to bf16 once per step: grad/param collective bytes "
         "halve on the reduce side",
         {"rules_preset": "dp_only", "accum_override": 1,
          "cast_params_once": True}),
    ]),
    ("deepseek-moe-16b", "train_4k", [
        ("tp_zero1_moe_a2a",
         "ITERATION 2 (tp_zero1 refuted the FSDP-gather theory: -45GiB "
         "only; the gathers are the MoE dispatch buffers resharded "
         "replicated->EP).  Scatter directly into the expert-aligned "
         "flat layout: gathers should become all-to-alls (1/16 bytes)",
         {"rules_preset": "tp_zero1"}),
        ("tp_zero1_moe_a2a_bf16",
         "ITERATION 3: bf16 live params + fp32 master in ZeRO-1 opt "
         "state: remaining param-side collectives halve",
         {"rules_preset": "tp_zero1", "params_bf16": True}),
    ]),
    ("qwen2-vl-72b", "train_4k", [
        ("bf16_params_master",
         "ITERATION 2 (cast-once refuted: XLA does not commute the "
         "convert with the FSDP all-gather).  Store live params in bf16 "
         "with the fp32 master ZeRO-1-sharded in the optimizer: gathers "
         "and grad reduces move bf16 -> ~2x on both",
         {"params_bf16": True}),
        ("bf16_params_accum8",
         "ITERATION 3: halve accumulation (sqrt-remat headroom): param "
         "gathers scale with accum",
         {"params_bf16": True, "accum_override": 8}),
        ("bf16_params_accum4",
         "ITERATION 4: accumulate 4 if activation residuals still fit",
         {"params_bf16": True, "accum_override": 4}),
    ]),
]


def run(out_path="experiments/hillclimb.json"):
    mesh = make_production_mesh()
    out = []
    for arch, shape, variants in PLANS:
        for name, hypothesis, kw in variants:
            try:
                _, compiled, rec = lower_cell(arch, shape, mesh, **kw)
                rec["mesh_name"] = "single"
                rec["status"] = "ok"
                row = analyze_record(rec)
                entry = {
                    "arch": arch, "shape": shape, "variant": name,
                    "hypothesis": hypothesis, "kwargs": kw,
                    "accum": rec["accum_steps"],
                    "compute_s": row.compute_s,
                    "memory_s": row.memory_s,
                    "collective_s": row.collective_s,
                    "collective_gib": rec["collectives"]["total_bytes"] / 2**30,
                    "collective_by_kind": {
                        k: v / 2**30 for k, v in
                        rec["collectives"]["bytes"].items()},
                    "bottleneck": row.bottleneck,
                    "useful_ratio": row.useful_ratio,
                    "roofline_fraction": row.roofline_fraction,
                    "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
                    "args_gib": rec["memory"]["argument_bytes"] / 2**30,
                    "fits_hbm": row.fits_hbm,
                }
                del compiled
            except Exception as e:      # noqa: BLE001
                entry = {"arch": arch, "shape": shape, "variant": name,
                         "error": f"{type(e).__name__}: {e}"}
            out.append(entry)
            print(json.dumps(entry), flush=True)
    parent = os.path.dirname(out_path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    prev = json.load(open(out_path)) if os.path.exists(out_path) else []
    json.dump(prev + out, open(out_path, "w"), indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/hillclimb.json",
                    help="hypothesis log to append to")
    args = ap.parse_args(argv if argv is not None
                         else ([] if __name__ != "__main__" else None))
    run(args.out)


if __name__ == "__main__":
    main()
